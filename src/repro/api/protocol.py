"""Typed, wire-serializable protocol objects (DESIGN.md §9).

These dataclasses are the *entire* vocabulary of the client/service
protocol — what a data owner, a querying user, and the untrusted search
service exchange:

  IndexSpec        owner -> service   what collection to create
  EncryptedCorpus  owner -> service   ciphertexts (+ owner-built index)
  EncryptedQuery   user  -> service   DCPE query ciphertexts + trapdoors
  SearchRequest    user  -> service   routed query + SearchParams
  SearchResult     service -> user    ids + the engine's SearchStats

Every type round-trips through versioned `to_bytes`/`from_bytes`
(npz-backed, see `core.wireformat`), so each leg of the protocol can
cross a process or wire boundary.  Arrays are bit-exact across a round
trip; a mismatched kind or version raises `WireFormatError` instead of
misparsing.

Ciphertext conventions (paper §IV/§V): for dimension d the DCPE
ciphertext keeps shape (d,) and the DCE trapdoor has 2*(d + d%2) + 16
components; `EncryptedQuery` is batch-native — a single query is the
nq=1 case, so the client/service protocol has one shape story, not two.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from ..core import dce
from ..core.dcpe import suggest_beta                      # noqa: F401
from ..core.ppanns import Keys                            # noqa: F401
from ..core.wireformat import WireFormatError, pack, unpack
from ..serving.search_engine import SearchStats

__all__ = ["PROTOCOL_VERSION", "WireFormatError", "IndexSpec",
           "PlacementSpec", "SearchParams", "EncryptedQuery",
           "EncryptedCorpus", "SearchRequest", "SearchResult",
           "SearchStats", "Keys", "suggest_beta"]

PROTOCOL_VERSION = 1

_BACKENDS = ("flat", "ivf", "hnsw", "graph")
_PLACEMENT_KINDS = ("single", "sharded")
_QUANTIZATIONS = (None, "int8", "pq8")
_SCHEDULERS = ("flush", "continuous")
_SECURITY_PROFILES = ("perf", "balanced", "hardened", "oblivious-sketch")
_OBLIVIOUS_PROFILES = ("hardened", "oblivious-sketch")


# ---------------------------------------------------------------------------
# PlacementSpec — WHERE a collection executes (deployment as a parameter).
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class PlacementSpec:
    """Deployment placement of one collection (DESIGN.md §10).

    `single` runs the engine's single-device path; `sharded` row-shards
    the ciphertexts across `n_shards` mesh devices on axis `data_axis`
    and runs the shard_map filter + sharded refine gather.  Placement is
    a *parameter* of `SecureAnnService.create_collection` — the same
    `submit(SearchRequest)` surface, micro-batcher, tenancy, ingestion,
    and persistence work over either.

    `n_shards=None` (sharded) means "every local device"; the service
    pins the effective count at creation (`resolve`), which is what
    `save` persists — a reloaded collection re-shards identically.

    `n_replicas` (DESIGN.md §16) is the availability knob: each shard
    group keeps that many logical replicas registered with the backend's
    health registry, and searches route around dead replicas — a shard
    answers while >= 1 of its replicas lives; only a fully-dead group
    degrades the answer (`SearchResult.degraded`).  Wire-versioned
    additively: payloads from before the field default to 1.
    """
    kind: str = "single"
    data_axis: str = "data"
    n_shards: int | None = None
    n_replicas: int = 1

    def __post_init__(self):
        self.validate()

    def validate(self):
        if self.kind not in _PLACEMENT_KINDS:
            raise ValueError(f"unknown placement kind {self.kind!r} "
                             f"(have {_PLACEMENT_KINDS})")
        if self.n_replicas < 1:
            raise ValueError(f"n_replicas must be >= 1, got "
                             f"{self.n_replicas}")
        if self.kind == "single":
            if self.n_shards not in (None, 1):
                raise ValueError("single placement cannot set n_shards "
                                 f"(got {self.n_shards})")
            if self.n_replicas != 1:
                raise ValueError("single placement cannot set n_replicas "
                                 f"(got {self.n_replicas}) — replication "
                                 "is a sharded-placement knob")
        else:
            if not self.data_axis:
                raise ValueError("sharded placement needs a non-empty "
                                 "data_axis name")
            if self.n_shards is not None and self.n_shards < 1:
                raise ValueError(f"n_shards must be >= 1, got "
                                 f"{self.n_shards}")

    @property
    def is_sharded(self) -> bool:
        return self.kind == "sharded"

    def resolve(self, n_devices: int) -> "PlacementSpec":
        """Pin `n_shards=None` to the device count at creation time."""
        if not self.is_sharded:
            return self
        n = int(self.n_shards or n_devices)
        if n > n_devices:
            raise ValueError(f"placement wants {n} shards but only "
                             f"{n_devices} device(s) exist")
        return dataclasses.replace(self, n_shards=n)

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "PlacementSpec":
        known = {f.name for f in dataclasses.fields(cls)}
        extra = set(d) - known
        if extra:
            raise WireFormatError(
                f"PlacementSpec: unknown fields {sorted(extra)}")
        return cls(**d)

    def to_bytes(self) -> bytes:
        return pack("placement-spec", PROTOCOL_VERSION, arrays={},
                    meta=self.to_dict())

    @classmethod
    def from_bytes(cls, data: bytes) -> "PlacementSpec":
        _, meta = unpack(data, "placement-spec", PROTOCOL_VERSION)
        try:
            return cls.from_dict(meta)
        except (TypeError, ValueError) as e:
            raise WireFormatError(f"bad placement-spec payload: {e}") from e


# ---------------------------------------------------------------------------
# IndexSpec — the one config object behind every entry point.
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class IndexSpec:
    """Everything needed to create (or re-create) a collection.

    Identity (`tenant`, `name`) routes requests; `d` fixes the
    ciphertext shapes; `backend` picks the filter index the service
    builds; the crypto fields parameterize the owner's keygen; the
    batching fields tune the service's micro-batcher.  `seed` keys both
    the owner's keygen and the service's deterministic index state —
    `None` means fresh entropy (the service records the effective seed
    when persisting, so a reloaded collection rebuilds identically).

    `scheduler` picks how the service shares engine calls between
    concurrent requests (DESIGN.md §12): "flush" is the deadline/size
    micro-batcher over bucketed shapes; "continuous" is the
    slot-table serving loop — no deadline, one compiled shape,
    better open-loop p99.  Wire-versioned additively: payloads from
    before the field default to "flush".

    `quantization` compresses the *filter* ciphertexts server-side
    (DESIGN.md §11): None scans f32 DCPE ciphertexts; "int8"/"pq8"
    scan 1-byte/dim scalar-quantized or m-byte/vector product-
    quantized codes through the fused adc_topk path, oversampling
    k' by `refine_ratio` (None = the per-kind default, core.adc)
    into the unchanged exact DCE refine.  flat/ivf/graph backends
    (the batched graph traversal scores edges with the same ADC
    surrogates, DESIGN.md §15).

    `security_profile` picks the leakage tier (repro.sec, DESIGN.md
    §14): "perf" serves the engine unflattened; "balanced" adds
    dummy-query batch padding + fixed-shape results; "hardened" /
    "oblivious-sketch" additionally pad every flush to `max_batch` and
    run scan-oblivious full-bucket filters (flat/ivf, plus the graph
    backend's bounded-hop fixed-fanout traversal).  Returned real ids
    are identical under every profile.
    """
    tenant: str
    name: str
    d: int
    backend: str = "flat"
    # crypto (owner-side)
    sap_beta: float = 1.0
    sap_s: float = 1024.0
    seed: int | None = None
    # filter index (service-side)
    n_partitions: int = 64
    nprobe: int = 8
    hnsw_M: int = 16
    hnsw_ef_construction: int = 200
    use_kernel: bool = True
    # quantized ADC filter (service-side, keyless — DESIGN.md §11)
    quantization: str | None = None
    refine_ratio: float | None = None
    pq_m: int = 16
    # request scheduler / runtime
    scheduler: str = "flush"
    max_batch: int = 32
    max_wait_ms: float = 2.0          # flush scheduler only
    max_queue: int = 256
    compact_every: int = 4096
    # leakage tier (repro.sec, DESIGN.md §14).  Wire-versioned
    # additively: payloads from before the field default to "perf".
    security_profile: str = "perf"

    def __post_init__(self):
        self.validate()

    def validate(self):
        if not self.tenant or not self.name:
            raise ValueError("IndexSpec needs non-empty tenant and name")
        if self.backend not in _BACKENDS:
            raise ValueError(f"unknown backend {self.backend!r} "
                             f"(have {_BACKENDS})")
        if self.d < 2:
            raise ValueError("PP-ANNS requires d >= 2")
        if self.quantization not in _QUANTIZATIONS:
            raise ValueError(f"unknown quantization {self.quantization!r} "
                             f"(have {_QUANTIZATIONS})")
        if self.quantization is not None and self.backend == "hnsw":
            raise ValueError("quantization applies to flat|ivf|graph "
                             "backends (the per-query host walk reads "
                             "full-precision rows)")
        if self.refine_ratio is not None:
            if self.quantization is None:
                raise ValueError("refine_ratio is the ADC oversampling "
                                 "factor — it needs quantization set")
            if self.refine_ratio < 1.0:
                raise ValueError(f"refine_ratio must be >= 1, got "
                                 f"{self.refine_ratio}")
        if self.pq_m < 1:
            raise ValueError(f"pq_m must be >= 1, got {self.pq_m}")
        if self.scheduler not in _SCHEDULERS:
            raise ValueError(f"unknown scheduler {self.scheduler!r} "
                             f"(have {_SCHEDULERS})")
        if self.security_profile not in _SECURITY_PROFILES:
            raise ValueError(
                f"unknown security_profile {self.security_profile!r} "
                f"(have {_SECURITY_PROFILES})")
        if (self.security_profile in _OBLIVIOUS_PROFILES
                and self.backend == "hnsw"):
            raise ValueError(
                f"security_profile {self.security_profile!r} needs the "
                f"scan-oblivious filter variant, and the per-query host "
                f"walk is data-dependent by construction — use flat|ivf "
                f"backends, or backend='graph' for the bounded-hop "
                f"fixed-fanout traversal tier (DESIGN.md §14/§15)")

    @property
    def cdim(self) -> int:
        """DCE trapdoor / ciphertext component dimension for this d."""
        return dce.ciphertext_dim(self.d)

    def collection_kwargs(self) -> dict:
        """Constructor kwargs for the runtime `Collection`."""
        return dict(
            backend=self.backend, sap_beta=self.sap_beta,
            sap_s=self.sap_s, seed=self.seed, use_kernel=self.use_kernel,
            scheduler=self.scheduler,
            max_batch=self.max_batch, max_wait_ms=self.max_wait_ms,
            max_queue=self.max_queue, compact_every=self.compact_every,
            n_partitions=self.n_partitions, nprobe=self.nprobe,
            hnsw_M=self.hnsw_M,
            hnsw_ef_construction=self.hnsw_ef_construction,
            quantization=self.quantization,
            refine_ratio=self.refine_ratio, pq_m=self.pq_m,
            security_profile=self.security_profile)

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "IndexSpec":
        known = {f.name for f in dataclasses.fields(cls)}
        extra = set(d) - known
        if extra:
            raise WireFormatError(f"IndexSpec: unknown fields {sorted(extra)}")
        return cls(**d)

    def to_bytes(self) -> bytes:
        return pack("index-spec", PROTOCOL_VERSION, arrays={},
                    meta=self.to_dict())

    @classmethod
    def from_bytes(cls, data: bytes) -> "IndexSpec":
        _, meta = unpack(data, "index-spec", PROTOCOL_VERSION)
        return cls.from_dict(meta)


# ---------------------------------------------------------------------------
# SearchParams — the per-request knobs of Algorithm 2.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class SearchParams:
    """k plus the filter/refine knobs (paper Algorithm 2).  Requests
    micro-batch together only when their (k, ratio_k, ef_search) agree —
    the jitted executables specialize on them."""
    k: int = 10
    ratio_k: float = 8.0
    ef_search: int = 96
    refine: str = "tournament"      # | "none" (filter-only, Fig. 6)

    def __post_init__(self):
        if self.k < 1:
            raise ValueError("k must be >= 1")
        if self.refine not in ("tournament", "none"):
            raise ValueError(f"batched refine must be 'tournament' or "
                             f"'none', got {self.refine!r}")

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "SearchParams":
        return cls(**d)

    def to_bytes(self) -> bytes:
        return pack("search-params", PROTOCOL_VERSION, arrays={},
                    meta=self.to_dict())

    @classmethod
    def from_bytes(cls, data: bytes) -> "SearchParams":
        _, meta = unpack(data, "search-params", PROTOCOL_VERSION)
        return cls.from_dict(meta)


# ---------------------------------------------------------------------------
# EncryptedQuery — what the user sends (batch-native).
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class EncryptedQuery:
    """(nq, d) DCPE query ciphertexts + (nq, 2d+16) DCE trapdoors.

    This is all the server ever learns about a query (paper §V-C): the
    user-side O(d^2) encryption happens in `QueryClient.encrypt_query`.
    """
    C_sap: np.ndarray
    T: np.ndarray

    def __post_init__(self):
        self.C_sap = np.atleast_2d(np.asarray(self.C_sap, np.float32))
        self.T = np.atleast_2d(np.asarray(self.T, np.float32))
        if self.C_sap.shape[0] != self.T.shape[0]:
            raise ValueError(
                f"{self.C_sap.shape[0]} ciphertexts vs "
                f"{self.T.shape[0]} trapdoors")
        if self.T.shape[1] != dce.ciphertext_dim(self.C_sap.shape[1]):
            raise ValueError(
                f"trapdoor dim {self.T.shape[1]} does not match "
                f"d={self.C_sap.shape[1]} "
                f"(expect {dce.ciphertext_dim(self.C_sap.shape[1])})")

    @property
    def nq(self) -> int:
        return self.C_sap.shape[0]

    @property
    def d(self) -> int:
        return self.C_sap.shape[1]

    @property
    def nbytes(self) -> int:
        return self.C_sap.nbytes + self.T.nbytes

    def to_bytes(self) -> bytes:
        return pack("encrypted-query", PROTOCOL_VERSION,
                    arrays={"C_sap": self.C_sap, "T": self.T})

    @classmethod
    def from_bytes(cls, data: bytes) -> "EncryptedQuery":
        arrays, _ = unpack(data, "encrypted-query", PROTOCOL_VERSION)
        try:
            return cls(C_sap=arrays["C_sap"], T=arrays["T"])
        except (KeyError, ValueError) as e:
            raise WireFormatError(f"bad encrypted-query payload: {e}") from e


# ---------------------------------------------------------------------------
# EncryptedCorpus — what the owner uploads (ciphertexts + optional index).
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class EncryptedCorpus:
    """The owner's outsourced database (paper §V-A): DCPE filter
    ciphertexts, DCE refine ciphertexts, and — for hnsw collections —
    the owner-built filter graph (`HNSW.to_arrays` payload, a function
    of ciphertexts only).  The service stores this and nothing else."""
    C_sap: np.ndarray               # (n, d)
    C_dce: np.ndarray               # (n, 4, 2d+16)
    index: dict | None = None       # HNSW.to_arrays() arrays, or None

    def __post_init__(self):
        self.C_sap = np.atleast_2d(np.asarray(self.C_sap, np.float32))
        self.C_dce = np.asarray(self.C_dce, np.float32)
        n, d = self.C_sap.shape
        if self.C_dce.shape != (n, 4, dce.ciphertext_dim(d)):
            raise ValueError(
                f"C_dce shape {self.C_dce.shape} does not match n={n}, "
                f"d={d} (expect {(n, 4, dce.ciphertext_dim(d))})")

    @property
    def n(self) -> int:
        return self.C_sap.shape[0]

    @property
    def d(self) -> int:
        return self.C_sap.shape[1]

    def to_bytes(self) -> bytes:
        arrays = {"C_sap": self.C_sap, "C_dce": self.C_dce}
        if self.index is not None:
            arrays.update({f"index__{k}": v for k, v in self.index.items()})
        return pack("encrypted-corpus", PROTOCOL_VERSION, arrays=arrays,
                    meta={"has_index": self.index is not None})

    @classmethod
    def from_bytes(cls, data: bytes) -> "EncryptedCorpus":
        arrays, meta = unpack(data, "encrypted-corpus", PROTOCOL_VERSION)
        index = None
        if meta.get("has_index"):
            index = {k[len("index__"):]: v for k, v in arrays.items()
                     if k.startswith("index__")}
        try:
            return cls(C_sap=arrays["C_sap"], C_dce=arrays["C_dce"],
                       index=index)
        except (KeyError, ValueError) as e:
            raise WireFormatError(f"bad encrypted-corpus payload: {e}") from e


# ---------------------------------------------------------------------------
# SearchRequest / SearchResult — the submit() round trip.
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class SearchRequest:
    """One routed search: (tenant, collection) + query + params.

    coalesce=True lets a single-query request ride the service's
    micro-batcher (throughput under concurrency); batch requests and
    coalesce=False go straight to one locked engine call.

    trace_id (optional) names the request's trace when the service runs
    with observability on (DESIGN.md §13) — a client-propagated
    correlation id, carried additively on the wire (old payloads decode
    with trace_id=None, same pattern as coalesce).  It never influences
    the search result.
    """
    tenant: str
    collection: str
    query: EncryptedQuery
    params: SearchParams = dataclasses.field(default_factory=SearchParams)
    coalesce: bool = True
    trace_id: str | None = None

    def to_bytes(self) -> bytes:
        meta = {"tenant": self.tenant,
                "collection": self.collection,
                "params": self.params.to_dict(),
                "coalesce": bool(self.coalesce)}
        if self.trace_id is not None:
            meta["trace_id"] = str(self.trace_id)
        return pack("search-request", PROTOCOL_VERSION,
                    arrays={"C_sap": self.query.C_sap, "T": self.query.T},
                    meta=meta)

    @classmethod
    def from_bytes(cls, data: bytes) -> "SearchRequest":
        arrays, meta = unpack(data, "search-request", PROTOCOL_VERSION)
        try:
            return cls(tenant=meta["tenant"], collection=meta["collection"],
                       query=EncryptedQuery(C_sap=arrays["C_sap"],
                                            T=arrays["T"]),
                       params=SearchParams.from_dict(meta["params"]),
                       coalesce=bool(meta.get("coalesce", True)),
                       trace_id=meta.get("trace_id"))
        except (KeyError, TypeError, ValueError) as e:
            raise WireFormatError(f"bad search-request payload: {e}") from e


@dataclasses.dataclass
class SearchResult:
    """(nq, k) int64 neighbor ids (-1 fills slots where a query had
    fewer than k real candidates) + the engine's uniform SearchStats.

    For a coalesced single-query request the stats describe the flush
    the request rode in (stats.n_queries = how many requests shared the
    batched engine call).

    `degraded` (DESIGN.md §16) surfaces failover: True means some shard
    group had no live replica when this answer was computed, so the ids
    cover only the alive shards' rows — a labelled partial answer
    instead of a failed request.  Carried additively inside the stats
    payload (`SearchStats.degraded` / `n_shards_down` default to
    healthy), so pre-resilience peers interoperate."""
    ids: np.ndarray
    stats: SearchStats

    @property
    def degraded(self) -> bool:
        return bool(self.stats.degraded)

    def __post_init__(self):
        self.ids = np.atleast_2d(np.asarray(self.ids, np.int64))

    @property
    def nq(self) -> int:
        return self.ids.shape[0]

    @property
    def k(self) -> int:
        return self.ids.shape[1]

    def ids_lists(self) -> list[np.ndarray]:
        """Per-query ids with the -1 padding stripped — the user-side
        post-processing step."""
        return [row[row >= 0] for row in self.ids]

    def to_bytes(self) -> bytes:
        return pack("search-result", PROTOCOL_VERSION,
                    arrays={"ids": self.ids},
                    meta={"stats": dataclasses.asdict(self.stats)})

    @classmethod
    def from_bytes(cls, data: bytes) -> "SearchResult":
        arrays, meta = unpack(data, "search-result", PROTOCOL_VERSION)
        try:
            return cls(ids=arrays["ids"],
                       stats=SearchStats(**meta["stats"]))
        except (KeyError, TypeError) as e:
            raise WireFormatError(f"bad search-result payload: {e}") from e
