"""On-disk keystore — owner-side key custody (DESIGN.md §9).

A keystore is a directory of `<name>.ppkeys` files, each one `Keys`
wire payload (`core.ppanns.Keys.to_bytes`).  It lives with the *data
owner* (or a trusted user): the search service persists collections as
ciphertexts only and never touches a keystore — that separation is the
whole point of the role split.

`load` re-validates dimension on the way in (`expect_d`), so pointing a
d=512 collection at d=128 keys fails loudly instead of producing
garbage ciphertexts.
"""

from __future__ import annotations

import os
import pathlib

from ..core.ppanns import Keys

__all__ = ["Keystore"]

_SUFFIX = ".ppkeys"


class Keystore:
    def __init__(self, root: str | os.PathLike):
        self.root = pathlib.Path(root)
        self.root.mkdir(parents=True, exist_ok=True)

    def path(self, name: str) -> pathlib.Path:
        if not name or "/" in name or name != os.path.basename(name):
            raise ValueError(f"bad key name {name!r}")
        return self.root / f"{name}{_SUFFIX}"

    def save(self, name: str, keys: Keys) -> pathlib.Path:
        """Atomic write: a crashed save never leaves a torn key file."""
        path = self.path(name)
        tmp = path.with_suffix(_SUFFIX + ".tmp")
        tmp.write_bytes(keys.to_bytes())
        os.replace(tmp, path)
        return path

    def load(self, name: str, *, expect_d: int | None = None) -> Keys:
        path = self.path(name)
        if not path.exists():
            raise KeyError(f"no keys named {name!r} in {self.root}")
        return Keys.from_bytes(path.read_bytes(), expect_d=expect_d)

    def names(self) -> list[str]:
        return sorted(p.name[: -len(_SUFFIX)]
                      for p in self.root.glob(f"*{_SUFFIX}"))

    def delete(self, name: str):
        path = self.path(name)
        if not path.exists():
            raise KeyError(f"no keys named {name!r} in {self.root}")
        path.unlink()
