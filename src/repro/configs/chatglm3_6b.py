"""chatglm3-6b [dense] — RoPE on half the head dims ("2d rope"), GQA kv=2
(arXiv:2406.12793).  28L d_model=4096 32H(kv=2) d_ff=13696 vocab=65024."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="chatglm3-6b", family="dense",
    n_layers=28, d_model=4096, n_heads=32, n_kv_heads=2, d_ff=13696,
    vocab_size=65024, d_head=128, rope_fraction=0.5,
)
