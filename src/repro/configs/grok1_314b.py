"""grok-1-314b [moe] — 8 experts top-2 (hf:xai-org/grok-1).
64L d_model=6144 48H(kv=8) d_ff=32768 vocab=131072.  Experts (8) do not
divide the model axis (16): EP falls back to per-expert TP on d_ff
(see sharding rules)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="grok-1-314b", family="moe",
    n_layers=64, d_model=6144, n_heads=48, n_kv_heads=8, d_ff=32768,
    vocab_size=131072, d_head=128,
    n_experts=8, experts_per_token=2, moe_capacity_factor=1.25,
    fsdp=True,
)
