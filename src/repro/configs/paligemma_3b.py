"""paligemma-3b [vlm] — SigLIP frontend STUB: input_specs provides
precomputed (B, 256, 2048) patch embeddings (arXiv:2407.07726); gemma
backbone, MQA kv=1.  18L d_model=2048 8H(kv=1) d_ff=16384 vocab=257216.
Prefix-LM mask: bidirectional over the image prefix, causal after."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="paligemma-3b", family="vlm",
    n_layers=18, d_model=2048, n_heads=8, n_kv_heads=1, d_ff=16384,
    vocab_size=257216, d_head=256, n_vision_tokens=256,
    tie_embeddings=True,
)
