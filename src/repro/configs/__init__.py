"""Assigned-architecture registry: --arch <id> resolves here."""
from importlib import import_module

_MODULES = {
    "zamba2-1.2b": "zamba2_1p2b",
    "qwen2.5-14b": "qwen2p5_14b",
    "qwen3-1.7b": "qwen3_1p7b",
    "chatglm3-6b": "chatglm3_6b",
    "nemotron-4-340b": "nemotron4_340b",
    "whisper-small": "whisper_small",
    "kimi-k2-1t-a32b": "kimi_k2_1t",
    "grok-1-314b": "grok1_314b",
    "mamba2-370m": "mamba2_370m",
    "paligemma-3b": "paligemma_3b",
}

ARCHS = tuple(_MODULES)


def get_config(name: str):
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; choose from {ARCHS}")
    return import_module(f"repro.configs.{_MODULES[name]}").CONFIG
