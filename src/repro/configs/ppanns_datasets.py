"""The paper's own experiment configs (Table I + §VII-A settings).

Datasets are synthesized at the paper's dimensionalities (offline
container, DESIGN.md §6); beta values follow the paper's tuning rule
("filter-phase recall ceiling near 0.5"), realized here as a fraction of
the legal [sqrt(M), 2 M sqrt(d)] range found by the same grid search.
"""

import dataclasses


@dataclasses.dataclass(frozen=True)
class ANNConfig:
    name: str
    d: int
    n_paper: int          # the paper's database size
    n_cpu: int            # CPU-feasible default for this container
    beta_fraction: float  # fraction of the legal beta range (recall~0.5)
    sap_s: float = 1024.0
    hnsw_m: int = 16      # paper: 40 (1M+ scale)
    ef_construction: int = 200   # paper: 600
    ratio_k: float = 8.0


DATASETS = {
    "sift1m": ANNConfig("sift1m", d=128, n_paper=1_000_000, n_cpu=20_000,
                        beta_fraction=0.03),
    "gist": ANNConfig("gist", d=960, n_paper=1_000_000, n_cpu=5_000,
                      beta_fraction=0.03),
    "glove": ANNConfig("glove", d=100, n_paper=1_183_514, n_cpu=20_000,
                       beta_fraction=0.03),
    "deep1m": ANNConfig("deep1m", d=96, n_paper=1_000_000, n_cpu=20_000,
                        beta_fraction=0.03),
}


def get_ann_config(name: str) -> ANNConfig:
    return DATASETS[name]
