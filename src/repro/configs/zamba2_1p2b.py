"""zamba2-1.2b [hybrid] — Mamba2 backbone + shared attention block
(arXiv:2411.15242).  38L d_model=2048 32H(kv=32) d_ff=8192 vocab=32000,
ssm_state=64.  Runs long_500k (sub-quadratic: SSM state + shared-attn KV)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b", family="hybrid",
    n_layers=38, d_model=2048, n_heads=32, n_kv_heads=32, d_ff=8192,
    vocab_size=32000, d_head=64,
    ssm_state=64, ssm_expand=2, ssm_conv=4, ssm_head_dim=64, ssm_groups=1,
    attn_every=6, mlp_type="swiglu", tie_embeddings=True,
)
