"""mamba2-370m [ssm] — SSD, attention-free (arXiv:2405.21060).
48L d_model=1024 vocab=50280, ssm_state=128.  Runs long_500k (O(1) decode
state).  vocab 50280 is not mesh-divisible -> embeddings replicate."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-370m", family="ssm",
    n_layers=48, d_model=1024, n_heads=0, n_kv_heads=0, d_ff=0,
    vocab_size=50280, d_head=0,
    ssm_state=128, ssm_expand=2, ssm_conv=4, ssm_head_dim=64, ssm_groups=1,
    tie_embeddings=True,
)
