"""nemotron-4-340b [dense] — GQA kv=8, squared-ReLU MLP (arXiv:2402.16819).
96L d_model=18432 96H(kv=8) d_ff=73728 vocab=256000.  ~341B params:
FSDP(ZeRO-3) over data + TP over model is mandatory."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="nemotron-4-340b", family="dense",
    n_layers=96, d_model=18432, n_heads=96, n_kv_heads=8, d_ff=73728,
    vocab_size=256000, d_head=192, mlp_type="squared_relu",
    fsdp=True,
)
