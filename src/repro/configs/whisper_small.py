"""whisper-small [audio enc-dec] — conv frontend STUB: input_specs provides
precomputed (B, 1500, 768) frame embeddings (arXiv:2212.04356).
12L enc + 12L dec, d_model=768 12H(kv=12) d_ff=3072 vocab=51865.
Simplifications noted in DESIGN.md: sinusoidal (not learned) decoder
positions; pre-LN layernorm blocks."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-small", family="encdec",
    n_layers=12, n_enc_layers=12, d_model=768, n_heads=12, n_kv_heads=12,
    d_ff=3072, vocab_size=51865, d_head=64, mlp_type="gelu",
    norm_type="layernorm", enc_seq_len=1500, tie_embeddings=True,
)
