"""kimi-k2-1t-a32b [moe] — trillion-param MoE, 384 experts top-8
(arXiv:2501.kimi2, paper-table config).  61L d_model=7168 64H(kv=8)
d_ff=2048/expert vocab=163840.  ~1.03T total / ~32B active params."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b", family="moe",
    n_layers=61, d_model=7168, n_heads=64, n_kv_heads=8, d_ff=2048,
    vocab_size=163840, d_head=112,
    n_experts=384, experts_per_token=8, moe_capacity_factor=1.25,
    fsdp=True,
)
