"""HNSW proximity graph (Malkov & Yashunin, TPAMI'20) — the paper's filter
index (§V-A), built *over DCPE ciphertexts* so edges only reflect noised,
approximate neighborhoods.

Implementation notes
  * Host-side numpy: graph traversal is pointer-chasing and belongs on the
    CPU even in the TPU deployment (DESIGN.md §3); every hop's frontier is
    distance-evaluated in one vectorized call, which is the piece the
    accelerator (repro.kernels.l2_topk) replaces at scale.
  * The index never sees plaintexts in the PP-ANNS scheme: `build` is fed
    C_SAP; distance comparisons during build/search happen on ciphertexts.
  * Supports incremental insert and delete-with-repair (paper §V-D).
"""

from __future__ import annotations

import heapq

import numpy as np

__all__ = ["HNSW"]


class HNSW:
    def __init__(
        self,
        dim: int,
        M: int = 16,
        ef_construction: int = 200,
        seed: int = 0,
    ):
        self.dim = dim
        self.M = M
        self.M0 = 2 * M
        self.mL = 1.0 / np.log(M)
        self.efC = ef_construction
        self._rng = np.random.default_rng(seed)
        self._X = np.zeros((0, dim), np.float32)
        self._n = 0
        self.levels: list[int] = []
        # links[lev] is a list over node ids; entry is an int32 ndarray of
        # neighbor ids or None if the node does not reach that level.
        self.links: list[list] = []
        self.entry = -1
        self.max_level = -1
        self.n_dist_evals = 0          # instrumentation for benchmarks

    # ------------------------------------------------------------- storage

    @property
    def size(self) -> int:
        return self._n

    @property
    def vectors(self) -> np.ndarray:
        return self._X[: self._n]

    def _ensure_capacity(self, extra: int):
        need = self._n + extra
        if need <= self._X.shape[0]:
            return
        cap = max(need, 2 * self._X.shape[0], 1024)
        grown = np.zeros((cap, self.dim), np.float32)
        grown[: self._n] = self._X[: self._n]
        self._X = grown

    def _dists(self, q: np.ndarray, ids) -> np.ndarray:
        ids = np.asarray(ids, np.int64)
        self.n_dist_evals += ids.size
        diff = self._X[ids] - q
        return np.einsum("nd,nd->n", diff, diff)

    # ------------------------------------------------------------ building

    def build(self, X: np.ndarray, progress_every: int = 0):
        """Insert all rows of X (ciphertexts in the PP scheme)."""
        X = np.asarray(X, np.float32)
        self._ensure_capacity(len(X))
        for i, x in enumerate(X):
            self.insert(x)
            if progress_every and (i + 1) % progress_every == 0:
                print(f"hnsw: inserted {i + 1}/{len(X)}")
        return self

    def insert(self, x: np.ndarray) -> int:
        x = np.asarray(x, np.float32)
        self._ensure_capacity(1)
        node = self._n
        self._X[node] = x
        self._n += 1
        lvl = int(-np.log(self._rng.uniform(1e-12, 1.0)) * self.mL)
        self.levels.append(lvl)

        old_max = self.max_level          # layers that already have nodes
        while self.max_level < lvl:
            self.max_level += 1
            self.links.append([None] * node)
        for lev in range(len(self.links)):
            self.links[lev].append(
                np.zeros(0, np.int32) if lev <= lvl else None)

        if self.entry < 0:
            self.entry = node
            return node

        ep = [self.entry]
        for lev in range(old_max, lvl, -1):
            ep = [self._greedy(x, ep[0], lev)]
        # only connect on layers that existed before this insert; on brand-new
        # upper layers the node starts link-less and becomes the entry point.
        for lev in range(min(lvl, old_max), -1, -1):
            W = self._search_layer(x, ep, self.efC, lev)
            m = self.M if lev > 0 else self.M0
            selected = self._select_heuristic(W, m)
            self.links[lev][node] = np.asarray(selected, np.int32)
            for nb in selected:
                self._add_link(nb, node, lev)
            ep = [i for _, i in W]
        if lvl > self.levels[self.entry]:
            self.entry = node
        return node

    def _add_link(self, src: int, dst: int, lev: int):
        cur = self.links[lev][src]
        cap = self.M if lev > 0 else self.M0
        merged = np.append(cur, np.int32(dst))
        if merged.size <= cap:
            self.links[lev][src] = merged
            return
        # overflow: re-select diverse neighbors around src
        d = self._dists(self._X[src], merged)
        order = np.argsort(d)
        W = [(float(d[i]), int(merged[i])) for i in order]
        self.links[lev][src] = np.asarray(
            self._select_heuristic(W, cap), np.int32)

    def _select_heuristic(self, W, m: int) -> list[int]:
        """Algorithm 4: keep a candidate only if it is closer to the new
        point than to every already-selected neighbor (diversity); fill
        remaining slots with the closest pruned candidates."""
        selected: list[int] = []
        pruned: list[int] = []
        for d, c in W:
            if len(selected) >= m:
                break
            if selected:
                dc = self._dists(self._X[c], selected)
                if (dc < d).any():
                    pruned.append(c)
                    continue
            selected.append(c)
        for c in pruned:
            if len(selected) >= m:
                break
            selected.append(c)
        return selected

    # ----------------------------------------------------------- searching

    def _greedy(self, q: np.ndarray, ep: int, lev: int) -> int:
        cur = ep
        cur_d = float(self._dists(q, [cur])[0])
        while True:
            neigh = self.links[lev][cur]
            if neigh is None or neigh.size == 0:
                return cur
            d = self._dists(q, neigh)
            j = int(np.argmin(d))
            if d[j] >= cur_d:
                return cur
            cur, cur_d = int(neigh[j]), float(d[j])

    def _search_layer(self, q: np.ndarray, eps, ef: int, lev: int):
        """Standard ef-search; returns [(dist, id)] ascending."""
        eps = list(dict.fromkeys(int(e) for e in eps))
        d0 = self._dists(q, eps)
        visited = set(eps)
        cand = [(float(d), e) for d, e in zip(d0, eps)]
        heapq.heapify(cand)
        result = [(-float(d), e) for d, e in zip(d0, eps)]
        heapq.heapify(result)
        while len(result) > ef:
            heapq.heappop(result)
        while cand:
            d, c = heapq.heappop(cand)
            if d > -result[0][0] and len(result) >= ef:
                break
            neigh = self.links[lev][c]
            if neigh is None or neigh.size == 0:
                continue
            new = [int(n) for n in neigh if int(n) not in visited]
            if not new:
                continue
            visited.update(new)
            nd = self._dists(q, new)
            bound = -result[0][0]
            for dist, nid in zip(nd, new):
                dist = float(dist)
                if len(result) < ef or dist < bound:
                    heapq.heappush(cand, (dist, nid))
                    heapq.heappush(result, (-dist, nid))
                    if len(result) > ef:
                        heapq.heappop(result)
                    bound = -result[0][0]
        out = [(-nd, i) for nd, i in result]
        out.sort()
        return out

    def search(self, q: np.ndarray, k: int, ef: int = 64):
        """k-ANN of q; returns (ids (k,), dists (k,)) ascending."""
        if self._n == 0:
            return np.zeros(0, np.int64), np.zeros(0, np.float32)
        q = np.asarray(q, np.float32)
        ep = self.entry
        for lev in range(self.max_level, 0, -1):
            ep = self._greedy(q, ep, lev)
        W = self._search_layer(q, [ep], max(ef, k), 0)
        W = W[:k]
        ids = np.asarray([i for _, i in W], np.int64)
        ds = np.asarray([d for d, _ in W], np.float32)
        return ids, ds

    # ------------------------------------------------- maintenance (§V-D)

    def delete(self, node: int) -> list[int]:
        """Delete a vector; in-neighbors are repaired by re-running neighbor
        selection over their remaining candidates (paper §V-D).  Returns the
        repaired in-neighbor ids — the only other nodes whose link rows
        changed — so a derived mirror (graph.csr.CSRGraph) can refresh
        exactly the touched rows instead of rebuilding."""
        repaired: set[int] = set()
        for lev in range(len(self.links)):
            if self.links[lev][node] is None:
                continue
            for src, nb in enumerate(self.links[lev]):
                if nb is None or src == node:
                    continue
                if (nb == node).any():
                    repaired.add(src)
                    keep = nb[nb != node]
                    # repair: reconnect through the deleted node's neighbors
                    cands = np.unique(np.concatenate(
                        [keep, self.links[lev][node][
                            self.links[lev][node] != src]]))
                    cands = cands[cands != src]
                    if cands.size:
                        d = self._dists(self._X[src], cands)
                        order = np.argsort(d)
                        W = [(float(d[i]), int(cands[i])) for i in order]
                        cap = self.M if lev > 0 else self.M0
                        self.links[lev][src] = np.asarray(
                            self._select_heuristic(W, cap), np.int32)
                    else:
                        self.links[lev][src] = keep
            self.links[lev][node] = None
        self.levels[node] = -1
        self._X[node] = np.inf       # unreachable by distance
        if self.entry == node:
            alive = [i for i, l in enumerate(self.levels) if l >= 0]
            self.entry = max(alive, key=lambda i: self.levels[i]) if alive else -1
            self.max_level = self.levels[self.entry] if alive else -1
        return sorted(repaired)

    # -------------------------------------------------------- persistence

    def to_arrays(self) -> dict:
        flat, offsets = [], []
        for lev in range(len(self.links)):
            for nb in self.links[lev]:
                offsets.append(len(flat) if nb is not None else -1)
                if nb is not None:
                    flat.extend([len(nb)] + nb.tolist())
        return {
            "X": self._X[: self._n],
            "levels": np.asarray(self.levels, np.int32),
            "flat": np.asarray(flat, np.int32),
            "offsets": np.asarray(offsets, np.int64),
            # n_layers can exceed max_level+1: deleting the top node
            # lowers max_level but the (empty) upper link layers remain
            "meta": np.asarray(
                [self.M, self.efC, self.entry, self.max_level, self._n,
                 len(self.links)]),
        }

    @classmethod
    def from_arrays(cls, arrs: dict) -> "HNSW":
        meta = [int(v) for v in arrs["meta"]]
        M, efC, entry, max_level, n = meta[:5]
        n_layers = meta[5] if len(meta) > 5 else max_level + 1
        self = cls(dim=arrs["X"].shape[1], M=M, ef_construction=efC)
        self._X = np.asarray(arrs["X"], np.float32).copy()
        self._n = n
        self.levels = arrs["levels"].tolist()
        self.entry, self.max_level = entry, max_level
        flat, offsets = arrs["flat"], arrs["offsets"]
        self.links = []
        pos = 0
        for lev in range(n_layers):
            layer = []
            for node in range(n):
                off = offsets[pos]
                pos += 1
                if off < 0:
                    layer.append(None)
                else:
                    cnt = int(flat[off])
                    layer.append(flat[off + 1: off + 1 + cnt].copy())
            self.links.append(layer)
        return self
