"""IVF (inverted-file) coarse partitioner.

Not a paper baseline per se, but the TPU-native *distributed* filter: graph
traversal does not shard, partition-pruned scans do (DESIGN.md §3).  The
serving engine shards partitions across the mesh and each device scans its
resident partitions with the l2_topk kernel.
"""

from __future__ import annotations

import numpy as np

__all__ = ["IVFIndex", "kmeans"]


def kmeans(X: np.ndarray, n_clusters: int, n_iters: int = 10, seed: int = 0):
    """Plain Lloyd's; returns (centroids (c, d), assignment (n,))."""
    rng = np.random.default_rng(seed)
    X = np.asarray(X, np.float32)
    n = X.shape[0]
    cent = X[rng.choice(n, size=min(n_clusters, n), replace=False)].copy()
    xn = (X * X).sum(1)
    assign = np.zeros(n, np.int64)
    for _ in range(n_iters):
        d = xn[:, None] - 2.0 * X @ cent.T + (cent * cent).sum(1)[None, :]
        assign = d.argmin(1)
        for c in range(cent.shape[0]):
            mask = assign == c
            if mask.any():
                cent[c] = X[mask].mean(0)
    return cent, assign


class IVFIndex:
    def __init__(self, n_clusters: int = 64, n_iters: int = 10, seed: int = 0):
        self.n_clusters = n_clusters
        self.n_iters = n_iters
        self.seed = seed
        self.centroids: np.ndarray | None = None
        self.lists: list[np.ndarray] = []

    def build(self, X: np.ndarray):
        self.centroids, assign = kmeans(X, self.n_clusters, self.n_iters,
                                        self.seed)
        self.lists = [np.where(assign == c)[0]
                      for c in range(self.centroids.shape[0])]
        return self

    def probe(self, q: np.ndarray, nprobe: int = 4) -> np.ndarray:
        """Candidate ids from the nprobe nearest partitions."""
        d = ((self.centroids - q) ** 2).sum(1)
        order = np.argsort(d)[:nprobe]
        if len(order) == 0:
            return np.zeros(0, np.int64)
        return np.concatenate([self.lists[c] for c in order])

    def partition_of(self, q: np.ndarray, nprobe: int = 4) -> np.ndarray:
        d = ((self.centroids - q) ** 2).sum(1)
        return np.argsort(d)[:nprobe]
