"""Secure k-NN primitives over DCE ciphertexts (paper §IV-B end, §V-B).

Two refine/scan strategies:
  * `DCEMaxHeap` + `linear_scan_heap` / `refine_heap` — the paper's exact
    algorithms (max-heap keyed by DCE comparisons; O(log k) comparisons per
    candidate).  Comparison counts are instrumented for the cost tables.
  * `linear_scan_tournament` / `refine_tournament` — the TPU adaptation:
    chunked pairwise Z-matrix win-count selection on the MXU
    (repro.kernels.dce_comp).  Exact, because DCE comparisons are exact.
"""

from __future__ import annotations

import numpy as np

from . import dce

__all__ = [
    "DCEMaxHeap",
    "linear_scan_heap",
    "linear_scan_tournament",
    "refine_heap",
    "refine_tournament",
]


class DCEMaxHeap:
    """Binary max-heap whose comparator is the encrypted DistanceComp.

    The server never sees distance values — only signs of
    Z = DistanceComp(C_i, C_j, T_q) (Theorem 3).  `worst` is the root.
    """

    def __init__(self, C_db: np.ndarray, T_q: np.ndarray, k: int):
        self.C = C_db
        self.T = T_q
        self.k = k
        self.ids: list[int] = []
        self.n_comparisons = 0

    def _further(self, i: int, j: int) -> bool:
        """True iff dist(ids[i], q) > dist(ids[j], q)."""
        self.n_comparisons += 1
        z = dce.distance_comp(self.C[self.ids[i]], self.C[self.ids[j]], self.T)
        return bool(z > 0)

    def _sift_up(self, pos: int):
        while pos > 0:
            parent = (pos - 1) // 2
            if self._further(pos, parent):
                self.ids[pos], self.ids[parent] = self.ids[parent], self.ids[pos]
                pos = parent
            else:
                return

    def _sift_down(self, pos: int):
        n = len(self.ids)
        while True:
            l, r = 2 * pos + 1, 2 * pos + 2
            big = pos
            if l < n and self._further(l, big):
                big = l
            if r < n and self._further(r, big):
                big = r
            if big == pos:
                return
            self.ids[pos], self.ids[big] = self.ids[big], self.ids[pos]
            pos = big

    def offer(self, cand: int):
        """Algorithm 2 lines 3-9: insert if heap not full, else replace the
        current worst when the candidate compares closer."""
        if len(self.ids) < self.k:
            self.ids.append(cand)
            self._sift_up(len(self.ids) - 1)
            return
        # DistanceComp(C_top, C_cand, T) > 0 <=> top is further than cand
        self.n_comparisons += 1
        z = dce.distance_comp(self.C[self.ids[0]], self.C[cand], self.T)
        if z > 0:
            self.ids[0] = cand
            self._sift_down(0)

    def result(self) -> np.ndarray:
        return np.asarray(self.ids, np.int64)


def linear_scan_heap(C_db: np.ndarray, T_q: np.ndarray, k: int):
    """Paper §IV-B: exact secure k-NN by linear scan + DCE max-heap.

    Returns (ids (k,), n_comparisons).  O(n d log k) — the cost the index
    exists to avoid.
    """
    heap = DCEMaxHeap(C_db, T_q, k)
    for i in range(C_db.shape[0]):
        heap.offer(i)
    return heap.result(), heap.n_comparisons


def refine_heap(C_cands: np.ndarray, cand_ids: np.ndarray, T_q: np.ndarray,
                k: int):
    """Algorithm 2 refine phase over a candidate subset."""
    heap = DCEMaxHeap(C_cands, T_q, k)
    for i in range(C_cands.shape[0]):
        heap.offer(i)
    local = heap.result()
    return np.asarray(cand_ids)[local], heap.n_comparisons


def _tournament_topk(C: np.ndarray, T: np.ndarray, k: int,
                     use_kernel: bool = True) -> np.ndarray:
    import jax.numpy as jnp
    from repro.kernels.dce_comp import ops as dce_ops
    idx = dce_ops.top_k_by_wins(jnp.asarray(C), jnp.asarray(T),
                                min(k, C.shape[0]), use_kernel=use_kernel)
    return np.asarray(idx, np.int64)


def refine_tournament(C_cands: np.ndarray, cand_ids: np.ndarray,
                      T_q: np.ndarray, k: int, use_kernel: bool = True):
    """TPU refine: one pairwise Z-matrix + win-count ranking (exact)."""
    local = _tournament_topk(C_cands, T_q, k, use_kernel)
    n = C_cands.shape[0]
    return np.asarray(cand_ids)[local], n * (n - 1)


def linear_scan_tournament(C_db: np.ndarray, T_q: np.ndarray, k: int,
                           chunk: int = 512, use_kernel: bool = True):
    """Chunked exact scan: per chunk keep top-k by win counts, then merge
    with the running top-k (top-k of a union == top-k of per-part top-ks)."""
    n = C_db.shape[0]
    best = np.zeros(0, np.int64)
    comparisons = 0
    for start in range(0, n, chunk):
        ids = np.arange(start, min(start + chunk, n))
        pool = np.concatenate([best, ids])
        Cp = C_db[pool]
        local = _tournament_topk(Cp, T_q, k, use_kernel)
        comparisons += len(pool) * (len(pool) - 1)
        best = pool[local]
    return best, comparisons
