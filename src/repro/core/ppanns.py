"""The complete PP-ANNS scheme (paper §V, Figs. 1 & 3).

Three roles:
  * DataOwner — holds the secret keys; encrypts the database with DCPE
    (filter ciphertexts) and DCE (refine ciphertexts); builds the HNSW
    index over the DCPE ciphertexts; outsources everything to the server.
  * User — receives the keys from the owner; per query computes the DCPE
    ciphertext C_SAP_q and the DCE trapdoor T_q (O(d^2) work, §V-C) and
    sends (C_SAP_q, T_q, k).
  * Server — honest-but-curious; runs Algorithm 2 (k'-ANN filter on the
    DCPE-HNSW, then the exact DCE refine) as a thin wrapper over the
    unified `serving.search_engine.SecureSearchEngine` (DESIGN.md §2):
    `search` is the batch-of-one view of `search_batch`, so per-query and
    batched results are identical by construction.  The server never sees
    plaintexts or distance values; only comparison signs (the proven
    leakage L).

Communication (paper §V-C): user -> server is (36 d + O(1)) bytes/query,
server -> user is 4k bytes of ids.  Both are measured in `SearchStats`,
which is shared with — and reported uniformly across — every engine
backend (flat / IVF / HNSW).
"""

from __future__ import annotations

import dataclasses
import threading
import warnings

import numpy as np

from . import dce, dcpe, hnsw as hnsw_mod
from .wireformat import WireFormatError, pack, unpack

__all__ = ["Keys", "KEYS_WIRE_VERSION", "EncryptedDatabase", "DataOwner",
           "User", "Server", "SearchStats", "build_system"]

KEYS_WIRE_VERSION = 1


def __getattr__(name):
    # Lazy re-export: SearchStats lives with the engine (serving layer);
    # importing it eagerly here would make core <-> serving circular.
    if name == "SearchStats":
        from ..serving.search_engine import SearchStats
        return SearchStats
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


@dataclasses.dataclass
class Keys:
    dce_key: dce.DCEKey
    sap_key: dcpe.SAPKey

    @property
    def d(self) -> int:
        return self.dce_key.d

    # ------------------------------------------------- wire (DESIGN.md §9)
    # The owner->user key handoff and the on-disk keystore both move keys
    # across a process boundary; this is the only sanctioned format.
    # float64 key matrices round-trip bit-exactly (npz keeps dtypes), so
    # ciphertexts produced before and after a round-trip are identical
    # for the same randomness seed.

    def to_bytes(self) -> bytes:
        k = self.dce_key
        return pack(
            "ppanns-keys", KEYS_WIRE_VERSION,
            arrays={
                "perm1": k.perm1, "perm2": k.perm2,
                "M1": k.M1, "M1_inv": k.M1_inv,
                "M2": k.M2, "M2_inv": k.M2_inv,
                "M3": k.M3, "M3_inv": k.M3_inv,
                "r": k.r, "kv": k.kv,
            },
            meta={"d": k.d, "d_pad": k.d_pad,
                  "sap_s": self.sap_key.s, "sap_beta": self.sap_key.beta})

    @classmethod
    def from_bytes(cls, data: bytes, *, expect_d: int | None = None
                   ) -> "Keys":
        """Deserialize; refuses a mismatched wire version (via `unpack`)
        and, when `expect_d` is given, keys for any other dimension —
        loading d=128 keys into a d=512 collection must fail loudly, not
        produce garbage ciphertexts."""
        arrays, meta = unpack(data, "ppanns-keys", KEYS_WIRE_VERSION)
        d, d_pad = int(meta["d"]), int(meta["d_pad"])
        if expect_d is not None and d != int(expect_d):
            raise WireFormatError(
                f"keys are for d={d}, expected d={int(expect_d)}")
        if d_pad != d + (d % 2):
            raise WireFormatError(f"inconsistent key dims d={d}, "
                                  f"d_pad={d_pad}")
        h, big = d_pad // 2 + 4, 2 * d_pad + 16
        shapes = {"perm1": (d_pad,), "perm2": (d_pad + 8,),
                  "M1": (h, h), "M1_inv": (h, h), "M2": (h, h),
                  "M2_inv": (h, h), "M3": (big, big), "M3_inv": (big, big),
                  "r": (4,), "kv": (4, big)}
        for name, shape in shapes.items():
            got = arrays[name].shape if name in arrays else None
            if got != shape:
                raise WireFormatError(
                    f"key component {name!r}: expected shape {shape} for "
                    f"d={d}, payload has {got}")
        dce_key = dce.DCEKey(d=d, d_pad=d_pad, **{
            name: np.asarray(arrays[name]) for name in shapes})
        sap_key = dcpe.SAPKey(s=float(meta["sap_s"]),
                              beta=float(meta["sap_beta"]))
        return cls(dce_key=dce_key, sap_key=sap_key)


@dataclasses.dataclass
class EncryptedDatabase:
    """Everything the server stores (paper §V-A): C_SAP, HNSW over C_SAP,
    and C_DCE."""
    C_sap: np.ndarray            # (n, d)       DCPE ciphertexts
    index: hnsw_mod.HNSW | None  # HNSW built on C_sap (None: no graph)
    C_dce: np.ndarray            # (n, 4, 2d+16) DCE ciphertexts

    @property
    def n(self) -> int:
        return self.C_sap.shape[0]


class DataOwner:
    def __init__(self, d: int, sap_beta: float, sap_s: float = 1024.0,
                 seed: int = 0):
        self.keys = Keys(
            dce_key=dce.keygen(d, seed=seed),
            sap_key=dcpe.keygen(s=sap_s, beta=sap_beta),
        )
        self._seed = seed
        self._enc_ctr = 10_000 + seed    # fresh-randomness counter (ingest)
        self._enc_lock = threading.Lock()

    @classmethod
    def from_keys(cls, keys: Keys, seed: int = 0) -> "DataOwner":
        """Rehydrate an owner around round-tripped keys (repro.api).

        `seed` keeps the deterministic `encrypt_database` schedule;
        the fresh-randomness counter for `encrypt_vectors` restarts
        from fresh entropy, NEVER from the seed — a restarted owner
        re-drawing an earlier incarnation's auto-seeds would let the
        server difference old and new ciphertexts."""
        self = cls.__new__(cls)
        self.keys = keys
        self._seed = int(seed)
        self._enc_ctr = 10_000 + int(
            np.random.SeedSequence().entropy % (2 ** 31))
        self._enc_lock = threading.Lock()
        return self

    def encrypt_database(
        self, P: np.ndarray, M: int = 16, ef_construction: int = 200,
        progress_every: int = 0, build_index: bool = True,
    ) -> EncryptedDatabase:
        P = np.atleast_2d(np.asarray(P))
        C_sap = dcpe.encrypt(P, self.keys.sap_key, seed=self._seed + 1)
        C_dce = dce.encrypt(P, self.keys.dce_key, seed=self._seed + 2)
        index = None
        if build_index:
            index = hnsw_mod.HNSW(dim=P.shape[1], M=M,
                                  ef_construction=ef_construction,
                                  seed=self._seed + 3)
            index.build(C_sap, progress_every=progress_every)
        return EncryptedDatabase(C_sap=C_sap, index=index, C_dce=C_dce)

    def encrypt_vector(self, p: np.ndarray, seed: int):
        """For incremental insert (paper §V-D): owner encrypts, server links."""
        C_sap = dcpe.encrypt(p[None], self.keys.sap_key, seed=seed)[0]
        C_dce = dce.encrypt(p[None], self.keys.dce_key, seed=seed + 1)[0]
        return C_sap, C_dce

    def encrypt_vectors(self, P: np.ndarray, seed: int | None = None):
        """Batched owner-side encryption for live ingestion (DESIGN.md §8).

        Routes through the jitted DCPE + DCE paths (`dcpe.encrypt_jax`,
        `dce.encrypt_jax` — encryption is matmul-shaped) with the batch
        padded to a power-of-two bucket capped at 4096 (larger batches
        chunk), so a burst of inserts reuses a handful of executables
        instead of recompiling per batch size, and bulk ingest never
        pads more than one chunk's worth of waste.
        Returns (C_sap (m, d), C_dce (m, 4, 2d+16)) numpy float32.
        """
        from ..kernels.common import next_bucket

        P = np.atleast_2d(np.asarray(P, np.float32))
        m = P.shape[0]
        chunk = 4096
        if m > chunk:
            parts = [self.encrypt_vectors(
                P[i: i + chunk],
                None if seed is None else seed + 7919 * (i // chunk))
                for i in range(0, m, chunk)]
            return (np.concatenate([a for a, _ in parts]),
                    np.concatenate([b for _, b in parts]))
        if seed is None:
            # atomic: concurrent ingestion threads must never share a
            # seed (identical noise across two batches would let the
            # server difference the ciphertexts)
            with self._enc_lock:
                self._enc_ctr += 2
                seed = self._enc_ctr
        bucket = next_bucket(m, minimum=8)
        # pad by replicating real rows, never zeros: DCE's randomization
        # scale is sqrt(mean(hat^2)) over the whole batch, so zero rows
        # would shrink the Eq. 2 blinding noise below the spec strength
        Pp = np.concatenate(
            [P, P[np.arange(bucket - m) % m]], axis=0) \
            if bucket != m else P
        C_sap = np.asarray(dcpe.encrypt_jax(Pp, self.keys.sap_key,
                                            seed=seed))[:m]
        C_dce = np.asarray(dce.encrypt_jax(Pp, self.keys.dce_key,
                                           seed=seed + 1))[:m]
        return C_sap, C_dce

    def share_keys(self) -> Keys:
        """Owner -> trusted user key handoff (threat model §II-B)."""
        return self.keys


class User:
    def __init__(self, keys: Keys, seed: int = 17):
        self.keys = keys
        self._ctr = seed

    def encrypt_query(self, q: np.ndarray):
        """-> (C_SAP_q, T_q): the only user-side work per query (O(d^2))."""
        self._ctr += 2
        C_sap_q = dcpe.encrypt(q[None], self.keys.sap_key, seed=self._ctr)[0]
        T_q = dce.trapgen(q[None], self.keys.dce_key, seed=self._ctr + 1)[0]
        return C_sap_q, T_q


class Server:
    """Runs Algorithm 2 on ciphertexts only.

    A thin facade over the unified `SecureSearchEngine` with the paper's
    HNSW filter backend: `search` wraps the engine's batch-of-one path
    (so looped `search` and `search_batch` return identical ids), and
    `refine="heap"` keeps the paper's sequential max-heap refine with its
    comparison instrumentation.
    """

    def __init__(self, db: EncryptedDatabase, use_kernel: bool = True):
        from ..serving.search_engine import (HNSWGraphFilter,
                                             SecureSearchEngine)
        self.db = db
        self.engine = SecureSearchEngine(
            db.C_sap, db.C_dce, backend=HNSWGraphFilter(db.index),
            use_kernel=use_kernel)

    def search(
        self,
        C_sap_q: np.ndarray,
        T_q: np.ndarray,
        k: int,
        ratio_k: float = 8.0,
        ef_search: int = 96,
        refine: str = "tournament",    # | "heap" (paper) | "none" (Fig. 6)
    ) -> tuple[np.ndarray, SearchStats]:
        warnings.warn(
            "ppanns.Server.search is a legacy entry point; new code "
            "should go through repro.api (QueryClient.encrypt_query -> "
            "SecureAnnService.submit), which returns the same ids "
            "(parity-tested in tests/test_api.py)",
            DeprecationWarning, stacklevel=2)
        return self.engine.search(
            np.asarray(C_sap_q), np.asarray(T_q), k, ratio_k=ratio_k,
            ef_search=ef_search, refine=refine)

    def search_batch(
        self,
        Q_sap: np.ndarray,
        T_q: np.ndarray,
        k: int,
        ratio_k: float = 8.0,
        ef_search: int = 96,
    ) -> tuple[np.ndarray, SearchStats]:
        """Batched Algorithm 2: HNSW filter per query (host graph walk),
        one batched DCE tournament refine on the accelerator."""
        return self.engine.search_batch(
            Q_sap, T_q, k, ratio_k=ratio_k, ef_search=ef_search)

    # ------------------------------------------------- maintenance (§V-D)

    def insert(self, C_sap: np.ndarray, C_dce_vec: np.ndarray):
        node = self.db.index.insert(C_sap)
        self.db.C_sap = np.concatenate([self.db.C_sap, C_sap[None]], 0)
        self.db.C_dce = np.concatenate([self.db.C_dce, C_dce_vec[None]], 0)
        self.engine.update_database(self.db.C_sap, self.db.C_dce)
        return node

    def delete(self, node: int):
        """Deletion needs no data-owner participation (paper §V-D)."""
        self.db.index.delete(node)
        self.db.C_dce[node] = 0.0     # scrub ciphertext
        self.engine.update_database(self.db.C_sap, self.db.C_dce)


def build_system(P: np.ndarray, beta_fraction: float = 0.05,
                 beta: float | None = None, s: float = 1024.0,
                 M: int = 16, ef_construction: int = 200, seed: int = 0):
    """Convenience: owner encrypts P, returns (owner, user, server).

    .. deprecated:: use `repro.api` — `DataOwnerClient(spec)` +
       `encrypt_corpus` + `SecureAnnService.create_collection` builds the
       same system behind the typed protocol (and serializable keys /
       queries / collections); parity is asserted in tests/test_api.py.
    """
    warnings.warn(
        "ppanns.build_system is deprecated; use repro.api "
        "(DataOwnerClient / QueryClient / SecureAnnService)",
        DeprecationWarning, stacklevel=2)
    P = np.atleast_2d(np.asarray(P))
    if beta is None:
        beta = dcpe.suggest_beta(P, fraction=beta_fraction)
    owner = DataOwner(d=P.shape[1], sap_beta=beta, sap_s=s, seed=seed)
    db = owner.encrypt_database(P, M=M, ef_construction=ef_construction)
    user = User(owner.share_keys())
    return owner, user, Server(db)
