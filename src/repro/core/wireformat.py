"""Bytes-level wire container for protocol objects (DESIGN.md §9).

One format for everything that crosses a process boundary — keys,
queries, results, encrypted corpora, persisted collections: an npz
archive whose `__wire__` entry is a JSON header `{kind, version, meta}`.
numpy arrays ride as native npz members (dtype- and bit-exact, so a
float32 ciphertext round-trips to the identical bits), scalars and
strings ride in the JSON meta.  `unpack` refuses a payload whose kind or
version does not match what the caller expects — a v2 reader never
silently misparses a v1 payload, it gets a `WireFormatError` naming both
versions.

Lives in `core` (not `api`) because `core.ppanns.Keys` serializes itself
with it and core must never import the api layer.
"""

from __future__ import annotations

import io
import json

import numpy as np

__all__ = ["WireFormatError", "pack", "unpack"]

_HEADER = "__wire__"


class WireFormatError(ValueError):
    """Malformed, wrong-kind, or wrong-version wire payload."""


def pack(kind: str, version: int, arrays: dict, meta: dict | None = None
         ) -> bytes:
    """Serialize arrays + JSON-able meta into a self-describing byte
    string.  Array names must not collide with the header entry."""
    if _HEADER in arrays:
        raise WireFormatError(f"array name {_HEADER!r} is reserved")
    header = json.dumps(
        {"kind": kind, "version": int(version), "meta": meta or {}})
    buf = io.BytesIO()
    np.savez(buf, **{_HEADER: np.frombuffer(header.encode(), np.uint8)},
             **{k: np.asarray(v) for k, v in arrays.items()})
    return buf.getvalue()


def unpack(data: bytes, kind: str, version: int) -> tuple[dict, dict]:
    """-> (arrays, meta); refuses payloads of another kind or version."""
    try:
        with np.load(io.BytesIO(data), allow_pickle=False) as z:
            if _HEADER not in z.files:
                raise WireFormatError("not a repro wire payload "
                                      f"(missing {_HEADER} header)")
            header = json.loads(bytes(z[_HEADER].tobytes()).decode())
            arrays = {k: z[k] for k in z.files if k != _HEADER}
    except (OSError, ValueError, KeyError) as e:
        if isinstance(e, WireFormatError):
            raise
        raise WireFormatError(f"malformed wire payload: {e}") from e
    if header.get("kind") != kind:
        raise WireFormatError(
            f"expected kind {kind!r}, payload is {header.get('kind')!r}")
    if header.get("version") != int(version):
        raise WireFormatError(
            f"{kind}: expected wire version {version}, payload is "
            f"version {header.get('version')} — refusing to deserialize")
    return arrays, header.get("meta", {})
