"""ASPE and its distance-transformation variants (paper §III-A).

Implemented *as the attack targets*: the paper's Theorems 1-2 and
Corollaries 1-2 prove these schemes are not KPA secure.  We reproduce the
schemes faithfully so `repro.core.attacks` can demonstrate full plaintext
recovery (our Table-less "Fig. for §III").

Scheme (Wong et al., SIGMOD'09, distance-comparing form):
  lift   p' = [-2p, ||p||^2, 1],  q' = [q, 1, r2/r1]  (scaled by r1)
  so     p'.q' * r1 = r1*(||p||^2 - 2 p.q + r2)  — a *linear* transform of
  dist(p,q) up to the query-independent ||q||^2 shift, which preserves
  comparisons for a fixed q.
  encrypt with an invertible M:  Enc(p') = M^T p',  Enc(q') = M^{-1} q'.

Variants expose L(C_p, T_q) = g(dist) for g in {linear, exp, log, square}.
"""

from __future__ import annotations

import dataclasses
from typing import Literal

import numpy as np

__all__ = ["ASPEKey", "keygen", "encrypt_db", "encrypt_query", "leak"]

Transform = Literal["linear", "exp", "log", "square"]


@dataclasses.dataclass
class ASPEKey:
    d: int
    M: np.ndarray        # (d+2, d+2) invertible
    M_inv: np.ndarray
    r1: float            # positive scale
    r2: float            # shift
    r3: float            # extra shift used by the 'square' variant


def keygen(d: int, seed: int = 0) -> ASPEKey:
    rng = np.random.default_rng(seed)
    while True:
        M = rng.standard_normal((d + 2, d + 2))
        if abs(np.linalg.det(M)) > 1e-6:
            break
    return ASPEKey(
        d=d, M=M, M_inv=np.linalg.inv(M),
        r1=float(rng.uniform(0.5, 2.0)),
        r2=float(rng.uniform(-1.0, 1.0)),
        r3=float(rng.uniform(-1.0, 1.0)),
    )


def _lift_db(P: np.ndarray) -> np.ndarray:
    n = P.shape[0]
    return np.concatenate(
        [-2.0 * P, (P * P).sum(1, keepdims=True), np.ones((n, 1))], axis=1)


def _lift_query(Q: np.ndarray, key: ASPEKey) -> np.ndarray:
    m = Q.shape[0]
    return key.r1 * np.concatenate(
        [Q, np.ones((m, 1)), np.full((m, 1), key.r2)], axis=1)


def encrypt_db(P: np.ndarray, key: ASPEKey) -> np.ndarray:
    """C_p = (p'^T M)^T — rows are encrypted DB vectors."""
    return _lift_db(np.atleast_2d(P)) @ key.M


def encrypt_query(Q: np.ndarray, key: ASPEKey) -> np.ndarray:
    """T_q = M^{-1} q' — rows are encrypted queries."""
    return _lift_query(np.atleast_2d(Q), key) @ key.M_inv.T


def leak(C_P: np.ndarray, T_Q: np.ndarray, key: ASPEKey,
         transform: Transform = "linear") -> np.ndarray:
    """What the server can compute: L(C_p, T_q) for all pairs, shape (n, m).

    raw = C_p . T_q = r1*(||p||^2 - 2 p.q + r2)  — linear in dist(p,q) up to
    the per-query constant r1*(r2 - ||q||^2); its transforms below are the
    "enhanced" ASPE variants the paper breaks in Thm 1/2 + Cor 1/2.
    """
    raw = C_P @ T_Q.T
    if transform == "linear":
        return raw
    if transform == "exp":
        # exp of the linear leak, shifted for float range; the constant shift
        # is absorbed by the attack's free unknown (Cor. 1 proof).
        return np.exp(raw - raw.max())
    if transform == "log":
        # log of the (positivized) linear leak; the constant shift is again
        # absorbed by the attack's free unknown (Cor. 2 proof).
        return np.log(raw - raw.min() + 1.0)
    if transform == "square":
        return key.r1 * raw * raw + key.r3
    raise ValueError(transform)
