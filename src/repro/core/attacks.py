"""Known-plaintext attacks on ASPE variants (paper §III-A, Thm 1-2, Cor 1-2).

These attacks *are part of the reproduction*: the paper motivates DCE by
proving that every ASPE variant leaking a transformation of distances is
KPA-broken.  Each attack here takes the server's view (ciphertexts + leaked
comparison scores) plus a small set of leaked plaintexts, and recovers the
remaining plaintexts to numerical precision.

Attack shapes
  linear / exp / log  (Thm 1, Cor 1-2):  d+2 leaked plaintexts suffice.
  square              (Thm 2):           0.5 d^2 + 2.5 d + 3 leaked
                                         plaintexts suffice.
"""

from __future__ import annotations

import numpy as np

from . import aspe

__all__ = [
    "recover_queries_linear",
    "recover_db_linear",
    "square_feature_dim",
    "recover_queries_square",
    "recover_db_square",
    "random_guess_error",
    "normalized_success",
    "attack_report",
]


def _invert_transform(L: np.ndarray, transform: str) -> np.ndarray:
    """Undo the monotone transform up to an additive constant, which the
    linear systems below absorb into their free (constant-slot) unknown."""
    if transform == "linear":
        return L
    if transform == "exp":
        return np.log(L)          # = raw - c
    if transform == "log":
        return np.exp(L)          # = raw + c
    raise ValueError(transform)


def recover_queries_linear(
    P_leak: np.ndarray, L_leak: np.ndarray, transform: str = "linear"
) -> tuple[np.ndarray, np.ndarray]:
    """Theorem 1 / Corollaries 1-2: recover all queries from d+2 leaked
    plaintexts.

    P_leak : (m, d) with m >= d+2 leaked database vectors.
    L_leak : (m, nq) leaked scores L(C_{p_i}, T_q).
    Returns (Q_hat (nq, d), X (nq, d+2)) where X are the recovered unknown
    vectors x = [r1 q, r1, r1 r2 (-c)] reused by `recover_db_linear`.
    """
    P_leak = np.atleast_2d(P_leak)
    m, d = P_leak.shape
    if m < d + 2:
        raise ValueError(f"need >= d+2 = {d + 2} leaked plaintexts, got {m}")
    b = _invert_transform(np.atleast_2d(L_leak), transform)      # (m, nq)
    # Rows of the coefficient matrix: [-2 p_i^T, ||p_i||^2, 1].
    A = np.concatenate(
        [-2.0 * P_leak, (P_leak ** 2).sum(1, keepdims=True), np.ones((m, 1))],
        axis=1)                                                   # (m, d+2)
    X, *_ = np.linalg.lstsq(A, b, rcond=None)                     # (d+2, nq)
    X = X.T                                                       # (nq, d+2)
    Q_hat = X[:, :d] / X[:, d:d + 1]                              # q = x[:d]/r1
    return Q_hat, X


def recover_db_linear(
    X: np.ndarray, L_db: np.ndarray, transform: str = "linear"
) -> np.ndarray:
    """Theorem 1, phase 2: recover arbitrary DB vectors from >= d+2
    recovered query unknowns X (from `recover_queries_linear`).

    L_db : (n, nq) leaked scores of the unknown DB vectors vs those queries.
    """
    X = np.atleast_2d(X)
    nq, dp2 = X.shape
    d = dp2 - 2
    if nq < d + 2:
        raise ValueError(f"need >= d+2 = {d + 2} recovered queries, got {nq}")
    b = _invert_transform(np.atleast_2d(L_db), transform)         # (n, nq)
    # raw(p, q_j) = -2 p . x_j[:d] + ||p||^2 x_j[d] + x_j[d+1]
    # unknowns y = [p (d), ||p||^2 (1)] per DB vector.
    A = np.concatenate([-2.0 * X[:, :d], X[:, d:d + 1]], axis=1)  # (nq, d+1)
    rhs = b - X[:, d + 1][None, :]                                # (n, nq)
    Y, *_ = np.linalg.lstsq(A, rhs.T, rcond=None)                 # (d+1, n)
    return Y.T[:, :d]


# ---------------------------------------------------------------------------
# Theorem 2: the 'square' variant.  L = r1 * raw^2 + r3 with
# raw = r1(||p||^2 - 2 p.q + r2).  L is linear in the degree<=4 monomial
# features of p below (dimension 0.5 d^2 + 2.5 d + 3, as in the paper).
# ---------------------------------------------------------------------------

def square_feature_dim(d: int) -> int:
    """Full-rank variant of the paper's 0.5 d^2 + 2.5 d + 3 feature map.

    The paper lists both ||p||^2 and the p_i^2 block as features; these are
    linearly dependent (||p||^2 = sum p_i^2), so we drop the ||p||^2 slot
    and absorb its 2 r1^3 r2 coefficient into the p_i^2 block — one fewer
    feature, same attack.
    """
    return d * (d - 1) // 2 + 3 * d + 2     # == 0.5 d^2 + 2.5 d + 2


def _square_features(P: np.ndarray) -> np.ndarray:
    """phi(p) = [||p||^4, ||p||^2 p, p^2, {p_i p_j}_{i<j}, p, 1]."""
    P = np.atleast_2d(P)
    n, d = P.shape
    norm2 = (P ** 2).sum(1, keepdims=True)
    iu, ju = np.triu_indices(d, k=1)
    cross = P[:, iu] * P[:, ju]
    return np.concatenate(
        [norm2 ** 2, norm2 * P, P ** 2, cross, P, np.ones((n, 1))],
        axis=1)


def recover_queries_square(
    P_leak: np.ndarray, L_leak: np.ndarray
) -> tuple[np.ndarray, np.ndarray]:
    """Theorem 2: recover queries from 0.5 d^2+2.5 d+3 leaked plaintexts.

    Solves phi(P_leak) w_q = L(:, q); the feature weights satisfy
    w[0] = r1^3, w[1:d+1] = -4 r1^3 q  =>  q = -w[1:d+1] / (4 w[0]).
    Returns (Q_hat, W) with W reused by `recover_db_square`.
    """
    P_leak = np.atleast_2d(P_leak)
    m, d = P_leak.shape
    D = square_feature_dim(d)
    if m < D:
        raise ValueError(f"need >= {D} leaked plaintexts, got {m}")
    Phi = _square_features(P_leak)                               # (m, D)
    W, *_ = np.linalg.lstsq(Phi, np.atleast_2d(L_leak), rcond=None)  # (D, nq)
    W = W.T                                                      # (nq, D)
    Q_hat = -W[:, 1:d + 1] / (4.0 * W[:, :1])
    return Q_hat, W


def recover_db_square(
    Q_hat: np.ndarray, W: np.ndarray, L_db: np.ndarray, d: int
) -> np.ndarray:
    """Theorem 2, phase 2: recover arbitrary DB vectors from recovered
    queries.

    L(p, q) is quadratic in q:  L = r1^3[(||p||^2+r2) - 2 p.q]^2 + r3, so we
    regress L(p, .) against the query features [1, q, q_i q_j (i<=j)] and
    read p off the linear slot: c_i = -4 r1^3 (||p||^2 + r2) p_i, with
    r1^3 = W[:,0] and r2 = W[:,d+1-slot]/(2 r1^3) recovered in phase 1 and
    ||p||^2 = sum_i c_ii / (4 r1^3).
    """
    Q_hat = np.atleast_2d(Q_hat)
    nq = Q_hat.shape[0]
    need = 1 + d + d * (d + 1) // 2
    if nq < need:
        raise ValueError(f"need >= {need} recovered queries, got {nq}")
    r1c = float(np.median(W[:, 0]))                  # r1^3
    # p_i^2-slot coefficients are 4 r1^3 q_i^2 + 2 r1^3 r2 (the absorbed
    # ||p||^2 term): average the residual over i and the query set.
    sq_slot = W[:, d + 1:2 * d + 1]                  # (nq, d)
    r2 = float(np.median(
        (sq_slot - 4.0 * r1c * Q_hat ** 2).mean(1) / (2.0 * r1c)))
    iu, ju = np.triu_indices(d, k=1)
    PhiQ = np.concatenate(
        [np.ones((nq, 1)), Q_hat, Q_hat ** 2, Q_hat[:, iu] * Q_hat[:, ju]],
        axis=1)                                      # (nq, 1+2d+d(d-1)/2)
    C, *_ = np.linalg.lstsq(PhiQ, np.atleast_2d(L_db).T, rcond=None)
    C = C.T                                          # (n, feat)
    c_lin = C[:, 1:d + 1]                            # -4 r1^3 (||p||^2+r2) p
    c_sq = C[:, d + 1:2 * d + 1]                     # 4 r1^3 p_i^2
    norm2 = c_sq.sum(1, keepdims=True) / (4.0 * r1c)
    return -c_lin / (4.0 * r1c * (norm2 + r2))


def attack_roundtrip(
    d: int = 8, n: int = 64, nq: int = 24, transform: str = "linear",
    seed: int = 0,
) -> dict:
    """End-to-end §III demonstration used by tests and benchmarks: encrypt,
    leak, attack, report max recovery error."""
    rng = np.random.default_rng(seed)
    key = aspe.keygen(d, seed=seed)
    P = rng.standard_normal((n, d))
    Q = rng.standard_normal((nq, d))
    C_P = aspe.encrypt_db(P, key)
    T_Q = aspe.encrypt_query(Q, key)
    L = aspe.leak(C_P, T_Q, key, transform)      # (n, nq)

    if transform == "square":
        D = square_feature_dim(d)
        leak_idx = np.arange(D)
        Q_hat, W = recover_queries_square(P[leak_idx], L[leak_idx])
        P_rest = np.setdiff1d(np.arange(n), leak_idx)
        P_hat = recover_db_square(Q_hat, W, L[P_rest], d) \
            if len(P_rest) else np.zeros((0, d))
        q_err = float(np.abs(Q_hat - Q).max())
        p_err = float(np.abs(P_hat - P[P_rest]).max()) if len(P_rest) else 0.0
    else:
        leak_idx = np.arange(d + 2)
        Q_hat, X = recover_queries_linear(P[leak_idx], L[leak_idx], transform)
        P_rest = np.setdiff1d(np.arange(n), leak_idx)
        P_hat = recover_db_linear(X, L[P_rest], transform)
        q_err = float(np.abs(Q_hat - Q).max())
        p_err = float(np.abs(P_hat - P[P_rest]).max())
    return {"transform": transform, "query_err": q_err, "db_err": p_err}


# ---------------------------------------------------------------------------
# Normalized attack success (repro.sec, DESIGN.md §14).  A raw recovery
# error is meaningless across data scales: DCPE ciphertexts live at
# scale s*sigma while ASPE plaintexts are unit-scale, so "err = 0.3"
# could be total recovery or total failure.  Every attack therefore
# reports success = 1 - err / baseline, where the baseline is the error
# an attacker achieves with ZERO leakage (guessing a fresh sample from
# the data distribution): 1.0 = perfect recovery, 0.0 = no better than
# chance, clamped at 0 for attacks that do worse than guessing.
# ---------------------------------------------------------------------------

def random_guess_error(
    X: np.ndarray, n_trials: int = 8, seed: int = 12345,
) -> float:
    """Empirical zero-leakage baseline for max-abs recovery error on the
    target matrix `X`: the median error of guessing a row-shuffled
    resample of X itself (a draw from the same empirical distribution,
    uninformed about which row is which)."""
    X = np.atleast_2d(np.asarray(X, np.float64))
    rng = np.random.default_rng(seed)
    errs = []
    for _ in range(n_trials):
        guess = X[rng.permutation(X.shape[0])]
        errs.append(float(np.abs(guess - X).max()))
    return float(np.median(errs))


def normalized_success(err: float, baseline: float) -> float:
    """[0, 1] attack success: 1 at exact recovery, 0 at (or below) the
    zero-leakage guessing baseline."""
    if baseline <= 0.0:
        return 0.0
    return float(max(0.0, 1.0 - float(err) / float(baseline)))


def attack_report(
    d: int = 8, n: int = 64, nq: int = 24, transform: str = "linear",
    seed: int = 0,
) -> dict:
    """`attack_roundtrip` with the errors normalized against the
    random-guess baseline — the ASPE rows of BENCH_attacks.json."""
    raw = attack_roundtrip(d=d, n=n, nq=nq, transform=transform, seed=seed)
    rng = np.random.default_rng(seed)
    base_q = random_guess_error(rng.standard_normal((nq, d)))
    base_p = random_guess_error(rng.standard_normal((n, d)))
    return {
        **raw,
        "query_baseline": base_q,
        "db_baseline": base_p,
        "query_success": normalized_success(raw["query_err"], base_q),
        "db_success": normalized_success(raw["db_err"], base_p),
    }
