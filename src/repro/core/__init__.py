"""Paper core: DCE, DCPE, ASPE(+attacks), AME, indexes, and the PP-ANNS
scheme (DataOwner / User / Server)."""

from . import ame, aspe, attacks, dce, dcpe, hnsw, ivf, lsh  # noqa: F401
from . import ppanns, secure_knn  # noqa: F401
