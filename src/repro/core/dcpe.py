"""Distance-Comparison-Preserving Encryption (DCPE) — Scale-and-Perturb (SAP).

Paper §III-B / §V-A, Algorithm 1 (after Fuchsbauer et al., SCN'22).

SAP encrypts ``p -> s*p + lambda_p`` where ``lambda_p`` is drawn uniformly
from the ball B(0, s*beta/4).  Distances between ciphertexts approximate
``s * dist`` within ``+- s*beta/2`` (metric distance), which yields the
beta-DCP guarantee: ``dist(o,q) < dist(p,q) - beta  =>  the encrypted
comparison agrees``.  Ciphertexts keep the original dimensionality, so an
encrypted distance costs exactly a plaintext distance — this is what makes
the HNSW *filter* phase cheap.

As in the paper we never decrypt: the modified Algorithm 1 stores no
decryption helper.  IND-KPA security is inherited from [10].
"""

from __future__ import annotations

import dataclasses

import numpy as np

__all__ = ["SAPKey", "keygen", "encrypt", "suggest_beta", "beta_bounds"]


@dataclasses.dataclass
class SAPKey:
    s: float      # scaling factor (paper uses s = 1024)
    beta: float   # perturbation factor, in [sqrt(M), 2 M sqrt(d)]


def beta_bounds(P: np.ndarray) -> tuple[float, float]:
    """Legal beta range [sqrt(M), 2 M sqrt(d)] with M = max |coordinate|."""
    M = float(np.max(np.abs(P)))
    d = P.shape[-1]
    return float(np.sqrt(M)), float(2.0 * M * np.sqrt(d))


def keygen(s: float = 1024.0, beta: float = 1.0) -> SAPKey:
    return SAPKey(s=float(s), beta=float(beta))


def suggest_beta(P: np.ndarray, fraction: float = 0.05) -> float:
    """A beta at `fraction` of the legal range — the paper tunes beta per
    dataset so the filter-phase recall ceiling sits near 0.5 (Fig. 4)."""
    lo, hi = beta_bounds(P)
    return float(lo + fraction * (hi - lo))


def encrypt(X: np.ndarray, key: SAPKey, seed: int = 0) -> np.ndarray:
    """Enc_SAP(s, beta, p) for a batch — Algorithm 1, vectorized.

    Draws lambda uniformly from the ball of radius s*beta/4 via the
    standard (direction ~ N(0, I)/||.||, radius ~ R * U^(1/d)) construction.
    """
    X = np.atleast_2d(np.asarray(X, dtype=np.float64))
    n, d = X.shape
    rng = np.random.default_rng(seed)
    u = rng.standard_normal((n, d))                       # Line 1
    u /= np.linalg.norm(u, axis=1, keepdims=True) + 1e-30
    x = (key.s * key.beta / 4.0) * rng.uniform(0.0, 1.0, (n, 1)) ** (1.0 / d)
    lam = x * u                                           # Lines 2-4
    return (key.s * X + lam).astype(np.float32)           # Line 5
