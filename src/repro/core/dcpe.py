"""Distance-Comparison-Preserving Encryption (DCPE) — Scale-and-Perturb (SAP).

Paper §III-B / §V-A, Algorithm 1 (after Fuchsbauer et al., SCN'22).

SAP encrypts ``p -> s*p + lambda_p`` where ``lambda_p`` is drawn uniformly
from the ball B(0, s*beta/4).  Distances between ciphertexts approximate
``s * dist`` within ``+- s*beta/2`` (metric distance), which yields the
beta-DCP guarantee: ``dist(o,q) < dist(p,q) - beta  =>  the encrypted
comparison agrees``.  Ciphertexts keep the original dimensionality, so an
encrypted distance costs exactly a plaintext distance — this is what makes
the HNSW *filter* phase cheap.

As in the paper we never decrypt: the modified Algorithm 1 stores no
decryption helper.  IND-KPA security is inherited from [10].
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["SAPKey", "keygen", "encrypt", "encrypt_jax", "suggest_beta",
           "beta_bounds"]


@dataclasses.dataclass
class SAPKey:
    s: float      # scaling factor (paper uses s = 1024)
    beta: float   # perturbation factor, in [sqrt(M), 2 M sqrt(d)]


def beta_bounds(P: np.ndarray) -> tuple[float, float]:
    """Legal beta range [sqrt(M), 2 M sqrt(d)] with M = max |coordinate|."""
    M = float(np.max(np.abs(P)))
    d = P.shape[-1]
    return float(np.sqrt(M)), float(2.0 * M * np.sqrt(d))


def keygen(s: float = 1024.0, beta: float = 1.0) -> SAPKey:
    return SAPKey(s=float(s), beta=float(beta))


def suggest_beta(P: np.ndarray, fraction: float = 0.05) -> float:
    """A beta at `fraction` of the legal range — the paper tunes beta per
    dataset so the filter-phase recall ceiling sits near 0.5 (Fig. 4)."""
    lo, hi = beta_bounds(P)
    return float(lo + fraction * (hi - lo))


def encrypt(X: np.ndarray, key: SAPKey, seed: int = 0) -> np.ndarray:
    """Enc_SAP(s, beta, p) for a batch — Algorithm 1, vectorized.

    Draws lambda uniformly from the ball of radius s*beta/4 via the
    standard (direction ~ N(0, I)/||.||, radius ~ R * U^(1/d)) construction.
    """
    X = np.atleast_2d(np.asarray(X, dtype=np.float64))
    n, d = X.shape
    rng = np.random.default_rng(seed)
    u = rng.standard_normal((n, d))                       # Line 1
    u /= np.linalg.norm(u, axis=1, keepdims=True) + 1e-30
    x = (key.s * key.beta / 4.0) * rng.uniform(0.0, 1.0, (n, 1)) ** (1.0 / d)
    lam = x * u                                           # Lines 2-4
    return (key.s * X + lam).astype(np.float32)           # Line 5


@functools.partial(jax.jit)
def _encrypt_jax(X, s, beta, rng_key):
    n, d = X.shape
    ku, kx = jax.random.split(rng_key)
    u = jax.random.normal(ku, (n, d))
    u = u / (jnp.linalg.norm(u, axis=1, keepdims=True) + 1e-30)
    x = (s * beta / 4.0) * jax.random.uniform(kx, (n, 1)) ** (1.0 / d)
    return (s * X + x * u).astype(jnp.float32)


def encrypt_jax(X: np.ndarray, key: SAPKey, seed: int = 0):
    """Enc_SAP for a batch on the accelerator — the owner-side ingestion
    path (DESIGN.md §8).

    Same ball-noise construction as `encrypt` with a JAX RNG stream; the
    jitted executable is cached per (n, d), so callers bucket n (see
    `kernels.common.next_bucket`).  s and beta ride as traced scalars, so
    one executable serves every tenant key.  Returns a jax array.
    """
    X = jnp.atleast_2d(jnp.asarray(X, jnp.float32))
    return _encrypt_jax(X, jnp.float32(key.s), jnp.float32(key.beta),
                        jax.random.PRNGKey(seed))
