"""E2LSH index — the baseline index of RS-SANN / PRI-ANN (paper §VII).

Standard p-stable locality-sensitive hashing: L tables of k concatenated
hashes h(x) = floor((a.x + b) / w).  The paper's comparison point is that
LSH needs far more candidates than HNSW for the same recall, which is what
drives RS-SANN/PRI-ANN's communication and user-side cost.
"""

from __future__ import annotations

import numpy as np

__all__ = ["LSHIndex"]


class LSHIndex:
    def __init__(
        self,
        dim: int,
        n_tables: int = 8,
        n_hashes: int = 12,
        bucket_width: float = 4.0,
        seed: int = 0,
    ):
        rng = np.random.default_rng(seed)
        self.dim = dim
        self.L = n_tables
        self.k = n_hashes
        self.w = bucket_width
        self.A = rng.standard_normal((n_tables, dim, n_hashes)).astype(np.float32)
        self.b = rng.uniform(0, bucket_width, (n_tables, n_hashes)).astype(np.float32)
        self.tables: list[dict[bytes, list[int]]] = [dict() for _ in range(n_tables)]
        self._n = 0

    def _hash(self, X: np.ndarray) -> np.ndarray:
        """(n, d) -> (L, n, k) int32 bucket coordinates."""
        proj = np.einsum("nd,ldk->lnk", X.astype(np.float32), self.A)
        return np.floor((proj + self.b[:, None, :]) / self.w).astype(np.int32)

    def build(self, X: np.ndarray):
        H = self._hash(np.atleast_2d(X))
        for l in range(self.L):
            tab = self.tables[l]
            for i, hrow in enumerate(H[l]):
                tab.setdefault(hrow.tobytes(), []).append(self._n + i)
        self._n += X.shape[0]
        return self

    def query(self, q: np.ndarray) -> np.ndarray:
        """Union of bucket candidates across tables (unranked)."""
        H = self._hash(q[None])
        out: set[int] = set()
        for l in range(self.L):
            out.update(self.tables[l].get(H[l, 0].tobytes(), ()))
        return np.fromiter(out, np.int64, len(out))
