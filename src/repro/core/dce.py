"""Distance Comparison Encryption (DCE) — the paper's core contribution (Section IV).

DCE encrypts database vectors so that the *sign* of
``Z = DistanceComp(C_o, C_p, T_q) = 2 r_o r_p r_q (dist(o,q) - dist(p,q))``
exactly answers "is o closer to q than p?", while leaking only that
comparison bit (Theorem 3 / Theorem 4 of the paper).

Division of labour (mirrors the paper's system model, Fig. 1):
  * KeyGen / Enc run at the *data owner* — host-side, numpy float64.
  * TrapGen runs at the *user* — host-side, numpy float64.
  * DistanceComp runs at the *server* — batched JAX/Pallas, float32.

Hardware adaptation vs. the paper's C++ heap walk: comparisons are
restructured into batched MXU-friendly forms (``scores_vs_pivot`` for the
heap refine, ``pairwise_z_matrix`` for the tournament refine; see
repro.kernels.dce_comp for the Pallas tile kernel).

Numerical note: the paper only requires M1, M2, M3 to be random invertible
matrices. We draw them *orthogonal* (QR of a Gaussian) — a measure-zero
subfamily that keeps every security argument intact (the simulator story in
§VI never uses non-orthogonality) while making the float pipeline perfectly
conditioned, so float32 server-side comparisons keep their sign fidelity
even at d≈1000.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "DCEKey",
    "keygen",
    "encrypt",
    "encrypt_jax",
    "trapgen",
    "distance_comp",
    "scores_vs_pivot",
    "pairwise_z_matrix",
    "ciphertext_dim",
    "mac_cost_per_comparison",
]


def ciphertext_dim(d: int) -> int:
    """Dimension of each of the 4 ciphertext component vectors: 2d+16."""
    d_pad = d + (d % 2)
    return 2 * d_pad + 16


def mac_cost_per_comparison(d: int) -> int:
    """Multiply-accumulate count of one DistanceComp: 4d+32 (paper §IV-B)."""
    return 4 * d + 32


@dataclasses.dataclass
class DCEKey:
    """Secret key SK = {M1, M2, M3, pi1, pi2, r1..r4, kv1..kv4}."""

    d: int                 # original dimensionality
    d_pad: int             # d rounded up to even (vector-splitting needs pairs)
    perm1: np.ndarray      # pi1 : R^d_pad -> R^d_pad           (int indices)
    perm2: np.ndarray      # pi2 : R^(d_pad+8) -> R^(d_pad+8)   (int indices)
    M1: np.ndarray         # (h, h), h = d_pad/2 + 4
    M1_inv: np.ndarray
    M2: np.ndarray
    M2_inv: np.ndarray
    M3: np.ndarray         # (2d_pad+16, 2d_pad+16)
    M3_inv: np.ndarray
    r: np.ndarray          # (4,) shared scalars r1..r4
    kv: np.ndarray         # (4, 2d_pad+16), kv1*kv3 == kv2*kv4

    @property
    def cdim(self) -> int:
        return 2 * self.d_pad + 16


def _orthogonal(rng: np.random.Generator, n: int) -> np.ndarray:
    q, r = np.linalg.qr(rng.standard_normal((n, n)))
    # Sign-fix for a proper Haar draw.
    return q * np.sign(np.diag(r))


def keygen(d: int, seed: int = 0) -> DCEKey:
    """KeyGen(1^zeta, d) -> SK  (paper §IV-B (1))."""
    if d < 2:
        raise ValueError("DCE requires d >= 2")
    rng = np.random.default_rng(seed)
    d_pad = d + (d % 2)
    h = d_pad // 2 + 4
    big = 2 * d_pad + 16

    M1 = _orthogonal(rng, h)
    M2 = _orthogonal(rng, h)
    M3 = _orthogonal(rng, big)
    # kv entries log-uniform in [1/2, 2] — mild conditioning by design.
    kv123 = np.exp(rng.uniform(-np.log(2.0), np.log(2.0), size=(3, big)))
    kv4 = kv123[0] * kv123[2] / kv123[1]          # enforce kv1∘kv3 == kv2∘kv4
    kv = np.concatenate([kv123, kv4[None]], axis=0)
    r = rng.uniform(0.5, 2.0, size=4)

    return DCEKey(
        d=d,
        d_pad=d_pad,
        perm1=rng.permutation(d_pad),
        perm2=rng.permutation(d_pad + 8),
        M1=M1,
        M1_inv=M1.T.copy(),
        M2=M2,
        M2_inv=M2.T.copy(),
        M3=M3,
        M3_inv=M3.T.copy(),
        r=r,
        kv=kv,
    )


def _pair_split(x: np.ndarray, negate: bool) -> np.ndarray:
    """Step 1 of vector randomization (Eq. 1).

    p -> [p1+p2, p1-p2, p3+p4, p3-p4, ...];  queries additionally negated,
    so that  p̌ᵀ q̌ = -2 pᵀq.
    """
    n, d = x.shape
    pairs = x.reshape(n, d // 2, 2)
    s = pairs[..., 0] + pairs[..., 1]
    m = pairs[..., 0] - pairs[..., 1]
    out = np.empty((n, d), dtype=x.dtype)
    out[:, 0::2] = s
    out[:, 1::2] = m
    return -out if negate else out


def _randomized(
    x: np.ndarray, key: DCEKey, rng: np.random.Generator, is_query: bool
) -> np.ndarray:
    """Vector randomization phase (Eq. 1–4): R^d -> R^(d_pad+8)."""
    x = np.asarray(x, dtype=np.float64)
    squeeze = x.ndim == 1
    if squeeze:
        x = x[None]
    n, d = x.shape
    if d != key.d:
        raise ValueError(f"vector dim {d} != key dim {key.d}")
    if key.d_pad != d:                                  # odd d: zero-pad
        x = np.concatenate([x, np.zeros((n, 1), x.dtype)], axis=1)
    d = key.d_pad
    half = d // 2

    checked = _pair_split(x, negate=is_query)           # Step 1
    hat = checked[:, key.perm1]                         # Step 2: pi1
    scale = np.sqrt(np.mean(hat * hat) + 1e-9)          # blend-in scale for pads

    r1, r2, r3, r4 = key.r
    if is_query:
        # Step 3 (Eq. 3): q̂ -> (q̂1, q̂2) with per-query beta1, beta2.
        beta = rng.normal(0.0, scale, size=(n, 2))
        h1 = np.concatenate(
            [hat[:, :half], beta[:, :1], beta[:, :1],
             np.full((n, 1), r1), np.full((n, 1), r2)], axis=1)
        h2 = np.concatenate(
            [hat[:, half:], beta[:, 1:], -beta[:, 1:],
             np.full((n, 1), r3), np.full((n, 1), r4)], axis=1)
        # Step 4 (Eq. 4): q̄ = pi2([M1^{-1} q̂1 ; M2^{-1} q̂2]).
        t = np.concatenate([h1 @ key.M1_inv.T, h2 @ key.M2_inv.T], axis=1)
    else:
        # Step 3 (Eq. 2): p̂ -> (p̂1, p̂2) with per-vector alpha/r' randomness
        # and gamma_p = (||p||^2 - r'1 r1 - r'2 r2 - r'3 r3) / r4.
        alpha = rng.normal(0.0, scale, size=(n, 2))
        rp = rng.normal(0.0, scale, size=(n, 3))
        norm2 = np.sum(x * x, axis=1, keepdims=True)
        gamma = (norm2 - rp[:, :1] * r1 - rp[:, 1:2] * r2 - rp[:, 2:3] * r3) / r4
        h1 = np.concatenate(
            [hat[:, :half], alpha[:, :1], -alpha[:, :1], rp[:, :1], rp[:, 1:2]],
            axis=1)
        h2 = np.concatenate(
            [hat[:, half:], alpha[:, 1:], alpha[:, 1:], rp[:, 2:3], gamma],
            axis=1)
        # Step 4 (Eq. 4): p̄ = pi2([p̂1ᵀ M1 ; p̂2ᵀ M2]).
        t = np.concatenate([h1 @ key.M1, h2 @ key.M2], axis=1)

    bar = t[:, key.perm2]
    return bar[0] if squeeze else bar


def encrypt(
    P: np.ndarray, key: DCEKey, seed: int = 1, dtype=np.float32
) -> np.ndarray:
    """Enc(p, SK) -> C_p  (paper §IV-B (2)).

    Returns ciphertexts of shape (n, 4, 2d+16): the four component vectors
    (p̄'1, p̄'2, p̄'3, p̄'4) of Eq. 13.
    """
    P = np.atleast_2d(np.asarray(P, dtype=np.float64))
    rng = np.random.default_rng(seed)
    bar = _randomized(P, key, rng, is_query=False)      # (n, d+8)
    n = bar.shape[0]
    big = key.cdim
    up = bar @ key.M3[: key.d_pad + 8]                  # p̄ᵀ M_up   (Eq. 10)
    down = bar @ key.M3[key.d_pad + 8:]                 # p̄ᵀ M_down
    ones = np.ones((1, big))
    rp = rng.uniform(0.5, 2.0, size=(n, 1))             # r_p > 0   (Eq. 13)
    C = np.stack(
        [
            rp * (up + ones) / key.kv[0],
            rp * (up - ones) / key.kv[1],
            rp * (down + ones) / key.kv[2],
            rp * (down - ones) / key.kv[3],
        ],
        axis=1,
    )
    return C.astype(dtype)


@functools.partial(jax.jit)
def _encrypt_jax_core(X, perm1, perm2, M1, M2, M3, r, kv, rng_key):
    """Enc(p, SK) batched under jit — X already zero-padded to (n, d_pad).

    The same Eq. 1–4 / Eq. 13 pipeline as `encrypt`, restructured so the
    heavy steps are two (n, h) x (h, h) matmuls and one
    (n, d_pad+8) x (d_pad+8, 2d_pad+16) matmul — the owner-side analogue
    of the MXU-shaped server math (DESIGN.md §8).  float32 end to end:
    the orthogonal key matrices keep the pipeline conditioned, the same
    argument that lets the server compare in float32.
    """
    n, d = X.shape
    half = d // 2
    k_alpha, k_rp, k_scale = jax.random.split(rng_key, 3)

    # Step 1 (Eq. 1): pair split [p1+p2, p1-p2, ...].
    pairs = X.reshape(n, half, 2)
    checked = jnp.stack(
        [pairs[..., 0] + pairs[..., 1], pairs[..., 0] - pairs[..., 1]],
        axis=-1).reshape(n, d)
    hat = jnp.take(checked, perm1, axis=1)              # Step 2: pi1
    scale = jnp.sqrt(jnp.mean(hat * hat) + 1e-9)

    # Step 3 (Eq. 2): per-vector alpha / r' randomness and gamma_p.
    alpha = scale * jax.random.normal(k_alpha, (n, 2))
    rp = scale * jax.random.normal(k_rp, (n, 3))
    norm2 = jnp.sum(X * X, axis=1, keepdims=True)
    gamma = (norm2 - rp[:, :1] * r[0] - rp[:, 1:2] * r[1]
             - rp[:, 2:3] * r[2]) / r[3]
    h1 = jnp.concatenate(
        [hat[:, :half], alpha[:, :1], -alpha[:, :1], rp[:, :1], rp[:, 1:2]],
        axis=1)
    h2 = jnp.concatenate(
        [hat[:, half:], alpha[:, 1:], alpha[:, 1:], rp[:, 2:3], gamma],
        axis=1)
    # Step 4 (Eq. 4): p̄ = pi2([p̂1ᵀ M1 ; p̂2ᵀ M2]).
    t = jnp.concatenate([h1 @ M1, h2 @ M2], axis=1)
    bar = jnp.take(t, perm2, axis=1)

    # Component split (Eq. 10 / Eq. 13).
    up = bar @ M3[: d + 8]
    down = bar @ M3[d + 8:]
    r_p = jax.random.uniform(k_scale, (n, 1), minval=0.5, maxval=2.0)
    C = jnp.stack(
        [
            r_p * (up + 1.0) / kv[0],
            r_p * (up - 1.0) / kv[1],
            r_p * (down + 1.0) / kv[2],
            r_p * (down - 1.0) / kv[3],
        ],
        axis=1,
    )
    return C.astype(jnp.float32)


def _key_jax_arrays(key: DCEKey) -> tuple:
    """Device copies of the key material, cached on the key object."""
    cached = getattr(key, "_jax_arrays", None)
    if cached is None:
        cached = (
            jnp.asarray(key.perm1, jnp.int32),
            jnp.asarray(key.perm2, jnp.int32),
            jnp.asarray(key.M1, jnp.float32),
            jnp.asarray(key.M2, jnp.float32),
            jnp.asarray(key.M3, jnp.float32),
            jnp.asarray(key.r, jnp.float32),
            jnp.asarray(key.kv, jnp.float32),
        )
        object.__setattr__(key, "_jax_arrays", cached)
    return cached


def encrypt_jax(P: np.ndarray, key: DCEKey, seed: int = 1):
    """Batched Enc on the accelerator — the owner-side ingestion path.

    Produces ciphertexts under the *same* key as `encrypt` (fresh
    randomness from a JAX stream instead of numpy), so jax-encrypted and
    numpy-encrypted rows interoperate inside one database: DistanceComp
    between them stays sign-correct (asserted in
    tests/test_batched_encrypt.py).  The executable is cached per
    (n, d_pad); callers bucket n.  Returns a (n, 4, 2d+16) jax array.
    """
    P = np.atleast_2d(np.asarray(P, np.float32))
    n, d = P.shape
    if d != key.d:
        raise ValueError(f"vector dim {d} != key dim {key.d}")
    if key.d_pad != d:                                  # odd d: zero-pad
        P = np.concatenate([P, np.zeros((n, 1), P.dtype)], axis=1)
    return _encrypt_jax_core(jnp.asarray(P), *_key_jax_arrays(key),
                             jax.random.PRNGKey(seed))


def trapgen(
    Q: np.ndarray, key: DCEKey, seed: int = 2, dtype=np.float32
) -> np.ndarray:
    """TrapGen(q, SK) -> T_q  (paper §IV-B (3)).  Shape (m, 2d+16)."""
    Q = np.atleast_2d(np.asarray(Q, dtype=np.float64))
    rng = np.random.default_rng(seed)
    bar = _randomized(Q, key, rng, is_query=True)       # (m, d+8)
    m = bar.shape[0]
    w = np.concatenate([bar, -bar], axis=1)             # [q̄ᵀ, -q̄ᵀ]
    rq = rng.uniform(0.5, 2.0, size=(m, 1))             # r_q > 0
    T = rq * (w @ key.M3_inv.T) * (key.kv[1] * key.kv[3])   # Eq. 15
    return T.astype(dtype)


# ---------------------------------------------------------------------------
# Server-side comparison primitives (pure array math; numpy or jax arrays).
# The Pallas-tiled versions live in repro.kernels.dce_comp.
# ---------------------------------------------------------------------------

def distance_comp(C_o, C_p, T_q):
    """DistanceComp(C_o, C_p, T_q) -> Z  (paper §IV-B (4)).

    Z < 0  <=>  dist(o, q) < dist(p, q).   Z = 2 r_o r_p r_q (d_oq - d_pq).
    """
    return ((C_o[..., 0, :] * C_p[..., 2, :]
             - C_o[..., 1, :] * C_p[..., 3, :]) * T_q).sum(-1)


def scores_vs_pivot(O1, O2, p3, p4, t):
    """Batched Z of many candidates o_i against one pivot p (heap refine).

    O1, O2: (n, D) components 1/2 of the candidates; p3, p4: (D,) components
    3/4 of the pivot; t: (D,) trapdoor.  Returns (n,) Z scores.
    """
    return (O1 * (p3 * t)).sum(-1) - (O2 * (p4 * t)).sum(-1)


def pairwise_z_matrix(C, t):
    """All-pairs Z matrix for a candidate set — the MXU-native refine.

    Z[i, j] = DistanceComp(C_i, C_j, t)  =>  Z[i, j] < 0 iff dist_i < dist_j.
    Implemented as two (n, D) x (D, n) matmuls, so the TPU tournament refine
    (rank candidates by win counts) runs at matmul throughput.
    """
    term1 = (C[:, 0, :] * t) @ C[:, 2, :].T
    term2 = (C[:, 1, :] * t) @ C[:, 3, :].T
    return term1 - term2
