"""Asymmetric Matrix Encryption (AME) — the paper's strongest-security,
highest-cost baseline (§III-C; Zheng et al., TDSC 2024).

Faithfulness note (recorded in DESIGN.md §7): the TDSC construction is
rebuilt here from its published *interface and cost profile*, which is what
the paper's comparison depends on:
  * secret key: 32 matrices in R^{(2d+6) x (2d+6)}                  [check]
  * each DB vector  -> 32 vectors in R^{2d+6}                        [check]
  * each query      -> 16 matrices in R^{(2d+6) x (2d+6)}            [check]
  * one comparison  = 16 vector-matrix products + 16 inner products
    = 16[(2d+6)^2 + (2d+6)] = 64 d^2 + 416 d + 672 MACs  (paper: +676) [check]
  * leakage: comparison sign only                                    [check]

Construction: lift a(x) = [x, ||x||^2, 1, noise_pad] in R^{2d+6}; a sparse
query-dependent form S(q) satisfies a(o)^T S(q) b(p) = dist(o,q)-dist(p,q).
S is additively split into 16 random shares S_t, each hidden by a distinct
matrix pair: u_t(o) = r_o Ma_t^T a(o), v_t(p) = r_p Mb_t^{-1} b(p),
W_t(q) = r_q Ma_t^{-1} S_t Mb_t, and

    Compare(o,p,q) = sum_t u_t(o)^T W_t(q) v_t(p)
                   = r_o r_p r_q (dist(o,q) - dist(p,q)).
"""

from __future__ import annotations

import dataclasses

import numpy as np

__all__ = ["AMEKey", "keygen", "encrypt", "trapgen", "compare",
           "mac_cost_per_comparison", "N_SHARES"]

N_SHARES = 16


def mac_cost_per_comparison(d: int) -> int:
    m = 2 * d + 6
    return N_SHARES * (m * m + m)        # = 64 d^2 + 416 d + 672


@dataclasses.dataclass
class AMEKey:
    d: int
    Ma: np.ndarray       # (16, m, m)
    Ma_inv: np.ndarray
    Mb: np.ndarray       # (16, m, m)   -> 32 matrices total
    Mb_inv: np.ndarray

    @property
    def m(self) -> int:
        return 2 * self.d + 6


def _orthogonal(rng: np.random.Generator, n: int) -> np.ndarray:
    q, r = np.linalg.qr(rng.standard_normal((n, n)))
    return q * np.sign(np.diag(r))


def keygen(d: int, seed: int = 0) -> AMEKey:
    rng = np.random.default_rng(seed)
    m = 2 * d + 6
    Ma = np.stack([_orthogonal(rng, m) for _ in range(N_SHARES)])
    Mb = np.stack([_orthogonal(rng, m) for _ in range(N_SHARES)])
    return AMEKey(d=d, Ma=Ma, Ma_inv=np.transpose(Ma, (0, 2, 1)).copy(),
                  Mb=Mb, Mb_inv=np.transpose(Mb, (0, 2, 1)).copy())


def _lift(X: np.ndarray, m: int, rng: np.random.Generator) -> np.ndarray:
    """a(x) = [x, ||x||^2, 1, noise pad] in R^m (pads hit zero rows of S)."""
    X = np.atleast_2d(np.asarray(X, dtype=np.float64))
    n, d = X.shape
    pad = rng.standard_normal((n, m - d - 2))
    return np.concatenate(
        [X, (X * X).sum(1, keepdims=True), np.ones((n, 1)), pad], axis=1)


def _S_of_q(q: np.ndarray, m: int) -> np.ndarray:
    """Sparse S with a(o)^T S b(p) = dist(o,q) - dist(p,q)."""
    d = q.shape[0]
    S = np.zeros((m, m))
    S[:d, d + 1] = -2.0 * q        # -2 o.q   (times b's '1' slot)
    S[d, d + 1] = 1.0              # +||o||^2
    S[d + 1, :d] = 2.0 * q         # +2 p.q   (times a's '1' slot)
    S[d + 1, d] = -1.0             # -||p||^2
    return S


def encrypt(P: np.ndarray, key: AMEKey, seed: int = 1,
            dtype=np.float32) -> tuple[np.ndarray, np.ndarray]:
    """DB vector -> 32 vectors: (U (n,16,m), V (n,16,m))."""
    rng = np.random.default_rng(seed)
    m = key.m
    A = _lift(P, m, rng)                              # (n, m)
    B = _lift(P, m, rng)                              # fresh pad noise
    r = rng.uniform(0.5, 2.0, size=(A.shape[0], 1, 1))
    U = r * np.einsum("nm,tmk->ntk", A, key.Ma)       # u_t = Ma_t^T a
    V = r * np.einsum("nm,tkm->ntk", B, key.Mb_inv)   # v_t = Mb_t^{-1} b
    return U.astype(dtype), V.astype(dtype)


def trapgen(Q: np.ndarray, key: AMEKey, seed: int = 2,
            dtype=np.float32) -> np.ndarray:
    """Query -> 16 matrices W_t = r_q Ma_t^{-1} S_t Mb_t; shape (nq,16,m,m)."""
    Q = np.atleast_2d(np.asarray(Q, dtype=np.float64))
    rng = np.random.default_rng(seed)
    m = key.m
    out = np.empty((Q.shape[0], N_SHARES, m, m))
    for qi, q in enumerate(Q):
        S = _S_of_q(q, m)
        shares = rng.standard_normal((N_SHARES - 1, m, m))
        shares = np.concatenate([shares, (S - shares.sum(0))[None]], axis=0)
        rq = rng.uniform(0.5, 2.0)
        # batched matmul chain (a 3-operand np.einsum without optimize=True
        # would evaluate as a naive O(m^4) loop)
        out[qi] = rq * (key.Ma_inv @ shares @ key.Mb)
    return out.astype(dtype)


def compare(U_o: np.ndarray, V_p: np.ndarray, W_q: np.ndarray) -> np.ndarray:
    """sum_t u_t^T W_t v_t;  negative  <=>  dist(o,q) < dist(p,q).

    U_o: (..., 16, m); V_p: (..., 16, m); W_q: (16, m, m).
    Cost per comparison: 16 vec-mat products + 16 inner products (O(d^2)).
    """
    left = np.einsum("...tm,tmk->...tk", U_o, W_q)
    return np.einsum("...tk,...tk->...", left, V_p)
