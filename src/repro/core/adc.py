"""Approximate-distance-computation (ADC) codebooks over DCPE
ciphertexts (DESIGN.md §11).

The filter phase only needs distances *approximately* — exactness lives
in the DCE refine — yet the flat/IVF backends stream full-precision f32
DCPE ciphertexts at 4 bytes/dim.  This module trains server-side
codebooks that compress those ciphertexts to 1 byte/dim (int8 scalar
quantization) or m bytes/vector (m-subspace product quantization,
k=256 centroids per subspace, Faiss/ScaNN-style), cutting filter HBM
bandwidth 4-32x.

Privacy: training and encoding are *keyless* — a codebook is a
deterministic function of the DCPE ciphertexts the honest-but-curious
server already stores, exactly like the IVF centroids and the HNSW
graph.  No new leakage is created (DESIGN.md §11).

Recall model: quantized distances mis-rank near-ties, so the filter
oversamples — it returns k' * refine_ratio candidates into the
unchanged exact DCE refine, which restores the order.  The defaults
below (int8: 2x, pq8: 4x) hold recall@10 >= 0.95 on clustered data at
the engine's default ratio_k (tests/test_adc.py pins this).

Scalar (int8) quantization uses per-dim offsets with one *global*
scale, so the symmetric integer distance

    ||c8_i - q8||^2  ~  ||c_i - q||^2 / scale^2

is rank-equivalent to a pure int32 expression `cn_i - 2 * (q8 . c8_i)`
— the form the adc_topk Pallas kernel computes on the MXU's native
s8 x s8 -> s32 path (kernels/adc_topk).
"""

from __future__ import annotations

import dataclasses

import numpy as np

from .ivf import kmeans

__all__ = ["QUANTIZATIONS", "DEFAULT_REFINE_RATIO", "SQCodebook",
           "PQCodebook", "train_codebook", "codebook_from_arrays",
           "default_refine_ratio", "pq_subspaces"]

# None is "no quantization" (the f32 scan); the strings are the
# IndexSpec.quantization vocabulary.
QUANTIZATIONS = (None, "int8", "pq8")

# Oversampling defaults of the recall model above: filter k' is
# multiplied by this before the exact refine.
DEFAULT_REFINE_RATIO = {"int8": 2.0, "pq8": 4.0}

_PQ_K = 256                      # centroids per subspace (1-byte codes)


def default_refine_ratio(quantization: str | None) -> float:
    if quantization is None:
        return 1.0
    return DEFAULT_REFINE_RATIO[quantization]


def pq_subspaces(d: int, m: int) -> int:
    """Largest subspace count <= m that divides d (PQ needs equal
    subvector widths; d=128, m=16 -> 16 subspaces of 8 dims)."""
    m = max(1, min(int(m), d))
    while d % m:
        m -= 1
    return m


@dataclasses.dataclass
class SQCodebook:
    """int8 scalar quantization: c8 = round((c - offset) / scale).

    offset: (d,) per-dim midranges; scale: one global float (per-dim
    scales would break the rank-equivalent integer distance — see the
    module docstring).  `cn` returned by `encode` is the int32 code
    norm ||c8||^2, the precomputed term of the ADC distance (4 bytes
    per row next to d bytes of codes).
    """
    offset: np.ndarray
    scale: float
    trained_n: int = 0
    kind: str = dataclasses.field(default="int8", init=False)

    @classmethod
    def train(cls, C: np.ndarray) -> "SQCodebook":
        C = np.atleast_2d(np.asarray(C, np.float32))
        lo, hi = C.min(axis=0), C.max(axis=0)
        offset = (lo + hi) / 2.0
        spread = float(np.abs(C - offset).max())
        return cls(offset=offset.astype(np.float32),
                   scale=max(spread, 1e-12) / 127.0,
                   trained_n=C.shape[0])

    @property
    def d(self) -> int:
        return self.offset.shape[0]

    def code_bytes_per_vector(self) -> int:
        return self.d + 4               # int8 codes + int32 norm

    def encode(self, C: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        """-> (codes (n, d) int8, cn (n,) int32 code norms)."""
        C = np.atleast_2d(np.asarray(C, np.float32))
        q = np.rint((C - self.offset[None, :]) / self.scale)
        codes = np.clip(q, -127, 127).astype(np.int8)
        cn = (codes.astype(np.int32) ** 2).sum(axis=1, dtype=np.int64)
        return codes, cn.astype(np.int32)

    def encode_query(self, Q: np.ndarray) -> np.ndarray:
        """Symmetric query quantization (same grid as the codes)."""
        codes, _ = self.encode(Q)
        return codes

    def decode(self, codes: np.ndarray) -> np.ndarray:
        return codes.astype(np.float32) * self.scale + self.offset[None, :]

    def to_arrays(self) -> dict:
        return {"offset": self.offset,
                "scale": np.float64(self.scale),   # full-precision: the
                # grid must round-trip bit-identically (DESIGN.md §11)
                "trained_n": np.int64(self.trained_n)}

    @classmethod
    def from_arrays(cls, arrays: dict) -> "SQCodebook":
        return cls(offset=np.asarray(arrays["offset"], np.float32),
                   scale=float(arrays["scale"]),
                   trained_n=int(arrays["trained_n"]))


@dataclasses.dataclass
class PQCodebook:
    """m-subspace product quantization, k=256 centroids per subspace.

    centroids: (m, 256, d/m) f32.  A database row encodes to m uint8
    centroid ids; a query becomes an (m, 256) look-up table of partial
    squared distances, and ADC is a LUT gather-accumulate over codes —
    the adc_topk Pallas kernel does the gather as a one-hot MXU matmul
    so the LUT never leaves VMEM.
    """
    centroids: np.ndarray
    trained_n: int = 0
    kind: str = dataclasses.field(default="pq8", init=False)

    @classmethod
    def train(cls, C: np.ndarray, m: int = 16, seed: int = 0,
              n_iters: int = 8) -> "PQCodebook":
        C = np.atleast_2d(np.asarray(C, np.float32))
        n, d = C.shape
        m = pq_subspaces(d, m)
        sub = d // m
        k = min(_PQ_K, n)
        cents = np.zeros((m, _PQ_K, sub), np.float32)
        for j in range(m):
            cj, _ = kmeans(C[:, j * sub: (j + 1) * sub], k,
                           n_iters=n_iters, seed=seed + j)
            cents[j, : cj.shape[0]] = cj
            if cj.shape[0] < _PQ_K:     # tiny corpus: duplicate the
                cents[j, cj.shape[0]:] = cj[0]   # first centroid so
                # every code stays decodable (never selected: argmin
                # picks the original copy first)
        return cls(centroids=cents, trained_n=n)

    @property
    def m(self) -> int:
        return self.centroids.shape[0]

    @property
    def d(self) -> int:
        return self.m * self.centroids.shape[2]

    def code_bytes_per_vector(self) -> int:
        return self.m                   # one uint8 id per subspace

    def encode(self, C: np.ndarray) -> np.ndarray:
        """-> (n, m) uint8 centroid ids."""
        C = np.atleast_2d(np.asarray(C, np.float32))
        n, d = C.shape
        sub = d // self.m
        codes = np.zeros((n, self.m), np.uint8)
        for j in range(self.m):
            X = C[:, j * sub: (j + 1) * sub]
            cj = self.centroids[j]
            d2 = ((X[:, None, :] - cj[None]) ** 2).sum(-1)
            codes[:, j] = d2.argmin(1).astype(np.uint8)
        return codes

    def decode(self, codes: np.ndarray) -> np.ndarray:
        codes = np.atleast_2d(np.asarray(codes))
        parts = [self.centroids[j, codes[:, j].astype(np.int64)]
                 for j in range(self.m)]
        return np.concatenate(parts, axis=1)

    def lut(self, Q: np.ndarray) -> np.ndarray:
        """Per-query ADC table: (nq, m, 256) partial squared distances."""
        Q = np.atleast_2d(np.asarray(Q, np.float32))
        nq, d = Q.shape
        sub = d // self.m
        Qs = Q.reshape(nq, self.m, 1, sub)
        return ((Qs - self.centroids[None]) ** 2).sum(-1)

    def to_arrays(self) -> dict:
        return {"centroids": self.centroids,
                "trained_n": np.int64(self.trained_n)}

    @classmethod
    def from_arrays(cls, arrays: dict) -> "PQCodebook":
        return cls(centroids=np.asarray(arrays["centroids"], np.float32),
                   trained_n=int(arrays["trained_n"]))


def train_codebook(C: np.ndarray, quantization: str, *, m: int = 16,
                   seed: int = 0):
    """Server-side (keyless) codebook training over DCPE ciphertexts."""
    if quantization == "int8":
        return SQCodebook.train(C)
    if quantization == "pq8":
        return PQCodebook.train(C, m=m, seed=seed)
    raise ValueError(f"unknown quantization {quantization!r} "
                     f"(have {QUANTIZATIONS})")


def codebook_from_arrays(quantization: str, arrays: dict):
    """Inverse of `<codebook>.to_arrays` keyed by the quantization kind
    (the `.ppcol` restore path)."""
    if quantization == "int8":
        return SQCodebook.from_arrays(arrays)
    if quantization == "pq8":
        return PQCodebook.from_arrays(arrays)
    raise ValueError(f"unknown quantization {quantization!r} "
                     f"(have {QUANTIZATIONS})")
