"""Sharded checkpointing with atomic commit, auto-resume and elastic
remesh on restore.

Layout:
  <dir>/step_<n>.tmp-<pid>/   — write in progress
  <dir>/step_<n>/manifest.json, arr_<i>.npy …  — committed (atomic rename)

Fault-tolerance contract:
  * A crash mid-save leaves only a .tmp dir — never a corrupt manifest;
    restore ignores tmp dirs, cleanup removes them.
  * `restore_checkpoint(..., mesh, pspecs)` re-device_puts every leaf with
    the *new* mesh's NamedSharding: restoring onto a different topology
    (elastic up/down-scaling) is the same code path as same-size restart.
  * The manifest records the writing mesh shape for audit.
"""

from __future__ import annotations

import json
import os
import shutil

import jax
import numpy as np

__all__ = ["save_checkpoint", "restore_checkpoint", "latest_step",
           "cleanup_old"]

_MANIFEST = "manifest.json"


def _paths_of(tree):
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return [(jax.tree_util.keystr(path), leaf) for path, leaf in flat]


def save_checkpoint(ckpt_dir: str, step: int, tree, *, mesh=None,
                    extra: dict | None = None) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + f".tmp-{os.getpid()}"
    os.makedirs(tmp, exist_ok=True)
    entries = []
    for i, (name, leaf) in enumerate(_paths_of(tree)):
        arr = np.asarray(jax.device_get(leaf))
        fn = f"arr_{i:05d}.npy"
        np.save(os.path.join(tmp, fn), arr)
        entries.append({"key": name, "file": fn, "shape": list(arr.shape),
                        "dtype": str(arr.dtype)})
    manifest = {
        "step": step,
        "entries": entries,
        "mesh_shape": (dict(mesh.shape) if mesh is not None else None),
        "extra": extra or {},
    }
    with open(os.path.join(tmp, _MANIFEST), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):          # overwrite-safe
        shutil.rmtree(final)
    os.rename(tmp, final)              # atomic commit
    return final


def latest_step(ckpt_dir: str) -> int | None:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = []
    for d in os.listdir(ckpt_dir):
        if d.startswith("step_") and ".tmp" not in d and \
           os.path.exists(os.path.join(ckpt_dir, d, _MANIFEST)):
            steps.append(int(d.split("_")[1]))
    return max(steps) if steps else None


def restore_checkpoint(ckpt_dir: str, template, step: int | None = None, *,
                       mesh=None, pspecs=None):
    """Restore into the structure of `template`.  With (mesh, pspecs) the
    leaves are device_put with the new mesh's shardings — elastic restore.
    Returns (tree, manifest)."""
    from jax.sharding import NamedSharding
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(path, _MANIFEST)) as f:
        manifest = json.load(f)
    by_key = {e["key"]: e for e in manifest["entries"]}

    flat, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    if pspecs is not None:
        spec_flat = jax.tree_util.tree_flatten(
            pspecs, is_leaf=lambda s: hasattr(s, "_normalized_spec") or
            type(s).__name__ == "PartitionSpec")[0]
    else:
        spec_flat = [None] * len(flat)
    for (key_path, tmpl_leaf), spec in zip(flat, spec_flat):
        key = jax.tree_util.keystr(key_path)
        e = by_key[key]
        arr = np.load(os.path.join(path, e["file"]))
        want_shape = tuple(getattr(tmpl_leaf, "shape", arr.shape))
        if tuple(arr.shape) != want_shape:
            raise ValueError(f"{key}: ckpt {arr.shape} != want {want_shape}")
        if mesh is not None and spec is not None:
            leaves.append(jax.device_put(arr, NamedSharding(mesh, spec)))
        else:
            leaves.append(jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, leaves), manifest


def cleanup_old(ckpt_dir: str, keep: int = 3):
    """Drop all but the newest `keep` checkpoints + stale tmp dirs."""
    if not os.path.isdir(ckpt_dir):
        return
    steps = sorted(
        (d for d in os.listdir(ckpt_dir)
         if d.startswith("step_") and ".tmp" not in d))
    for d in steps[:-keep] if keep else steps:
        shutil.rmtree(os.path.join(ckpt_dir, d), ignore_errors=True)
    for d in os.listdir(ckpt_dir):
        if ".tmp" in d:
            shutil.rmtree(os.path.join(ckpt_dir, d), ignore_errors=True)
