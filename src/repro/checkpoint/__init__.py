from .ckpt import (  # noqa: F401
    save_checkpoint, restore_checkpoint, latest_step, cleanup_old,
)
