"""Model configuration — one dataclass covers every assigned architecture
family (dense / moe / ssm / hybrid / encdec / vlm)."""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    d_head: int = 0                # 0 -> d_model // n_heads

    # attention / mlp options
    mlp_type: str = "swiglu"       # swiglu | squared_relu | gelu
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_fraction: float = 1.0     # chatglm3: 0.5 ("2d rope")
    rope_theta: float = 10_000.0
    norm_type: str = "rmsnorm"     # rmsnorm | layernorm
    norm_eps: float = 1e-6
    tie_embeddings: bool = False

    # MoE
    n_experts: int = 0
    experts_per_token: int = 0
    moe_capacity_factor: float = 1.25

    # SSM (mamba2 / zamba2)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssm_head_dim: int = 64
    ssm_groups: int = 1

    # hybrid (zamba2): shared attention block every `attn_every` ssm layers
    attn_every: int = 0

    # enc-dec (whisper)
    n_enc_layers: int = 0
    enc_seq_len: int = 0           # stub frontend sequence (1500 frames)

    # vlm (paligemma)
    n_vision_tokens: int = 0       # stub frontend patch embeddings

    # numerics / distribution
    dtype: str = "bfloat16"
    fsdp: bool = False             # ZeRO-3 weight sharding over data axis
    remat: bool = True
    scan_layers: bool = True

    # sub-quadratic attention available? (long_500k eligibility)
    @property
    def subquadratic(self) -> bool:
        return self.family in ("ssm", "hybrid")

    @property
    def head_dim(self) -> int:
        return self.d_head or (self.d_model // max(self.n_heads, 1))

    @property
    def d_inner(self) -> int:      # SSM inner width
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def has_decoder(self) -> bool:
        return True                # all assigned archs decode (enc-dec incl.)

    def smoke(self) -> "ModelConfig":
        """A reduced same-family config for CPU smoke tests."""
        return dataclasses.replace(
            self,
            n_layers=min(self.n_layers, 2),
            n_enc_layers=min(self.n_enc_layers, 2),
            d_model=128,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, max(1, min(self.n_kv_heads, 2))),
            d_head=32,
            d_ff=256,
            vocab_size=512,
            n_experts=min(self.n_experts, 8) if self.n_experts else 0,
            experts_per_token=(min(self.experts_per_token, 2)
                               if self.experts_per_token else 0),
            ssm_state=min(self.ssm_state, 16) if self.ssm_state else 0,
            ssm_head_dim=32 if self.ssm_state else 64,
            enc_seq_len=min(self.enc_seq_len, 16) if self.enc_seq_len else 0,
            n_vision_tokens=(min(self.n_vision_tokens, 8)
                             if self.n_vision_tokens else 0),
            attn_every=min(self.attn_every, 2) if self.attn_every else 0,
            dtype="float32",
            fsdp=False,
        )


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One input-shape cell (assigned per-arch shape set)."""
    name: str
    kind: str                      # train | prefill | decode
    seq_len: int
    global_batch: int

    @property
    def is_serving(self) -> bool:
        return self.kind in ("prefill", "decode")


SHAPES = {
    "train_4k": ShapeConfig("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524_288, 1),
}
