"""Family assembly: param metas + forward/loss/prefill/decode for
dense / moe / vlm (decoder-only), ssm (mamba2), hybrid (zamba2) and
encdec (whisper).

Params are nested dicts whose leaves mirror a ParamMeta tree (the single
source of truth for shapes, logical sharding axes and dtypes).  Layer
stacks store weights with a leading L axis and run under jax.lax.scan
(+ optional jax.checkpoint) — compile time stays flat in depth.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from ..sharding.rules import AxisRules, ParamMeta, constrain
from . import layers as L
from . import moe as moe_mod
from . import ssm as ssm_mod
from .config import ModelConfig

# =====================================================================
# Param metas
# =====================================================================

def _fs(cfg: ModelConfig):
    """Logical axis for ZeRO-3 weight sharding of the d_model dim."""
    return "embed_fsdp" if cfg.fsdp else None


def _attn_metas(cfg: ModelConfig, stack: int | None, dt: str) -> dict:
    """Attention projections, fused-2D; leading stack axis optional."""
    def pm(shape, axes):
        if stack is not None:
            shape = (stack,) + shape
            axes = (None,) + axes
        return ParamMeta(shape, axes, dt)

    D, H, K, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    fs = _fs(cfg)
    out = {
        "wq": pm((D, H * dh), (fs, "heads")),
        "wk": pm((D, K * dh), (fs, "kv")),
        "wv": pm((D, K * dh), (fs, "kv")),
        "wo": pm((H * dh, D), ("heads", fs)),
    }
    if cfg.qkv_bias:
        out |= {"bq": pm((H * dh,), ("heads",)),
                "bk": pm((K * dh,), ("kv",)),
                "bv": pm((K * dh,), ("kv",))}
    if cfg.qk_norm:
        out |= {"q_norm": pm((dh,), (None,)),
                "k_norm": pm((dh,), (None,))}
    return out


def _mlp_metas(cfg: ModelConfig, stack: int | None, dt: str) -> dict:
    def pm(shape, axes):
        if stack is not None:
            shape = (stack,) + shape
            axes = (None,) + axes
        return ParamMeta(shape, axes, dt)

    D, F = cfg.d_model, cfg.d_ff
    fs = _fs(cfg)
    if cfg.mlp_type == "swiglu":
        return {"wg": pm((D, F), (fs, "ff")), "wu": pm((D, F), (fs, "ff")),
                "wo": pm((F, D), ("ff", fs))}
    return {"wi": pm((D, F), (fs, "ff")), "wo": pm((F, D), ("ff", fs))}


def _moe_metas(cfg: ModelConfig, stack: int | None, dt: str) -> dict:
    def pm(shape, axes):
        if stack is not None:
            shape = (stack,) + shape
            axes = (None,) + axes
        return ParamMeta(shape, axes, dt)

    D, F, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    fs = _fs(cfg)
    return {
        "router": pm((D, E), (None, None)),
        "wg": pm((E, D, F), ("expert", fs, "ff")),
        "wu": pm((E, D, F), ("expert", fs, "ff")),
        "wo": pm((E, F, D), ("expert", "ff", fs)),
    }


def _norm_metas(cfg: ModelConfig, stack: int | None, dt: str,
                dim: int | None = None) -> dict:
    shape = (dim or cfg.d_model,)
    axes: tuple = (None,)
    if stack is not None:
        shape = (stack,) + shape
        axes = (None, None)
    out = {"scale": ParamMeta(shape, axes, dt)}
    if cfg.norm_type == "layernorm":
        out["bias"] = ParamMeta(shape, axes, dt)
    return out


def _ssm_metas(cfg: ModelConfig, stack: int | None, dt: str) -> dict:
    def pm(shape, axes):
        if stack is not None:
            shape = (stack,) + shape
            axes = (None,) + axes
        return ParamMeta(shape, axes, dt)

    D, dI = cfg.d_model, cfg.d_inner
    GN = cfg.ssm_groups * cfg.ssm_state
    H = cfg.ssm_heads
    kw = cfg.ssm_conv
    fs = _fs(cfg)
    return {
        "wz": pm((D, dI), (fs, "ssm_inner")),
        "wx": pm((D, dI), (fs, "ssm_inner")),
        "wb": pm((D, GN), (fs, None)),
        "wc": pm((D, GN), (fs, None)),
        "wdt": pm((D, H), (fs, None)),
        "conv": pm((kw, dI + 2 * GN), (None, "conv_dim")),
        "a_log": pm((H,), (None,)),
        "dt_bias": pm((H,), (None,)),
        "d_skip": pm((H,), (None,)),
        "norm_scale": pm((dI,), ("ssm_inner",)),
        "wo": pm((dI, D), ("ssm_inner", fs)),
    }


def param_metas(cfg: ModelConfig) -> dict:
    dt = cfg.dtype
    V, D = cfg.vocab_size, cfg.d_model
    Ls = cfg.n_layers if cfg.scan_layers else None
    metas: dict[str, Any] = {
        "embed": {"tokens": ParamMeta((V, D), ("vocab", _fs(cfg)), dt)},
        "final_norm": _norm_metas(cfg, None, dt),
    }
    if not cfg.tie_embeddings:
        metas["unembed"] = {"kernel": ParamMeta((D, V), (_fs(cfg), "vocab"), dt)}

    if cfg.family in ("dense", "moe", "vlm"):
        layer = {
            "attn_norm": _norm_metas(cfg, Ls, dt),
            "attn": _attn_metas(cfg, Ls, dt),
            "mlp_norm": _norm_metas(cfg, Ls, dt),
            "mlp": (_moe_metas(cfg, Ls, dt) if cfg.family == "moe"
                    else _mlp_metas(cfg, Ls, dt)),
        }
        metas["layers"] = layer
    elif cfg.family == "ssm":
        metas["layers"] = {
            "norm": _norm_metas(cfg, Ls, dt),
            "mixer": _ssm_metas(cfg, Ls, dt),
        }
    elif cfg.family == "hybrid":
        metas["layers"] = {
            "norm": _norm_metas(cfg, Ls, dt),
            "mixer": _ssm_metas(cfg, Ls, dt),
        }
        metas["shared"] = {
            "attn_norm": _norm_metas(cfg, None, dt),
            "attn": _attn_metas(cfg, None, dt),
            "mlp_norm": _norm_metas(cfg, None, dt),
            "mlp": _mlp_metas(cfg, None, dt),
        }
    elif cfg.family == "encdec":
        Le = cfg.n_enc_layers if cfg.scan_layers else None
        metas["encoder"] = {
            "layers": {
                "attn_norm": _norm_metas(cfg, Le, dt),
                "attn": _attn_metas(cfg, Le, dt),
                "mlp_norm": _norm_metas(cfg, Le, dt),
                "mlp": _mlp_metas(cfg, Le, dt),
            },
            "final_norm": _norm_metas(cfg, None, dt),
        }
        metas["layers"] = {
            "attn_norm": _norm_metas(cfg, Ls, dt),
            "attn": _attn_metas(cfg, Ls, dt),
            "cross_norm": _norm_metas(cfg, Ls, dt),
            "cross": _attn_metas(cfg, Ls, dt),
            "mlp_norm": _norm_metas(cfg, Ls, dt),
            "mlp": _mlp_metas(cfg, Ls, dt),
        }
    else:
        raise ValueError(cfg.family)
    return metas


# =====================================================================
# Initialization (name-based; metas drive shapes)
# =====================================================================

def init_params(cfg: ModelConfig, key) -> dict:
    metas = param_metas(cfg)
    flat, treedef = jax.tree_util.tree_flatten_with_path(
        metas, is_leaf=lambda m: isinstance(m, ParamMeta))

    def one(path, meta: ParamMeta, k):
        name = path[-1].key
        shape, dt = meta.shape, meta.dtype
        if name in ("scale", "norm_scale", "d_skip"):
            return jnp.ones(shape, dt)
        if name.startswith("b") and len(shape) <= 2 or name == "bias":
            return jnp.zeros(shape, dt)
        if name == "a_log":
            return jnp.log(jax.random.uniform(k, shape, jnp.float32,
                                              1.0, 16.0)).astype(dt)
        if name == "dt_bias":
            u = jax.random.uniform(k, shape, jnp.float32, 1e-3, 0.1)
            return jnp.log(jnp.expm1(u)).astype(dt)       # softplus^-1
        if name in ("q_norm", "k_norm"):
            return jnp.ones(shape, dt)
        if name == "tokens":
            return (0.02 * jax.random.normal(k, shape, jnp.float32)).astype(dt)
        fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
        std = 1.0 / np.sqrt(fan_in)
        return (std * jax.random.normal(k, shape, jnp.float32)).astype(dt)

    leaves = []
    for i, (path, meta) in enumerate(flat):
        leaves.append(one(path, meta, jax.random.fold_in(key, i)))
    return jax.tree_util.tree_unflatten(treedef, leaves)


# =====================================================================
# Forward passes
# =====================================================================

def _maybe_remat(fn, cfg: ModelConfig):
    return jax.checkpoint(fn) if cfg.remat else fn


def _dense_layer(x, lp, cfg, mesh, rules, *, positions, cache=None,
                 prefix_len=0):
    h = L.norm(x, lp["attn_norm"], cfg)
    a, kv = L.attention(h, lp["attn"], cfg, mesh, rules,
                        q_positions=positions, cache=cache,
                        causal=True, prefix_len=prefix_len)
    x = x + a
    h = L.norm(x, lp["mlp_norm"], cfg)
    if cfg.family == "moe":
        x = x + moe_mod.moe_block(h, lp["mlp"], cfg, mesh, rules)
    else:
        x = x + L.mlp(h, lp["mlp"], cfg, mesh, rules)
    return x, kv


def _decoder_stack(params, x, cfg, mesh, rules, *, positions, cache=None,
                   prefix_len=0):
    """Scan the layer stack.  cache: None or dict of stacked (L, ...) KV."""
    pos_cache = None if cache is None else cache["pos"]

    def body(carry, xs):
        xc = carry
        if cache is None:
            lp = xs
            out, _ = layer_fn(xc, lp, None)
            return out, None
        lp, kc, vc = xs
        out, kv = layer_fn(xc, lp, {"k": kc, "v": vc, "pos": pos_cache})
        return out, kv

    def layer_fn(xc, lp, c):
        return _dense_layer(xc, lp, cfg, mesh, rules, positions=positions,
                            cache=c, prefix_len=prefix_len)

    body = _maybe_remat(body, cfg) if cache is None else body
    if cache is None:
        x, _ = jax.lax.scan(body, x, params["layers"])
        return x, None
    x, kvs = jax.lax.scan(body, x, (params["layers"], cache["k"], cache["v"]))
    new_cache = {"k": kvs["k"], "v": kvs["v"], "pos": pos_cache + x.shape[1]}
    return x, new_cache


def _ssm_layer(x, lp, cfg, mesh, rules, *, cache=None):
    h = L.norm(x, lp["norm"], cfg)
    if cache is None:
        y, new_cache = ssm_mod.mamba_block(h, lp["mixer"], cfg, mesh, rules)
    elif cache.get("decode", False):
        y, new_cache = ssm_mod.mamba_decode_step(h, lp["mixer"], cfg, mesh,
                                                 rules, cache)
    else:   # prefill: run the chunked scan, keep the final state
        y, new_cache = ssm_mod.mamba_block(h, lp["mixer"], cfg, mesh, rules)
    return x + y, new_cache


def _hybrid_shared_block(x, params, cfg, mesh, rules, *, positions,
                         cache=None):
    sp = params["shared"]
    h = L.norm(x, sp["attn_norm"], cfg)
    a, kv = L.attention(h, sp["attn"], cfg, mesh, rules,
                        q_positions=positions, cache=cache, causal=True)
    x = x + a
    h = L.norm(x, sp["mlp_norm"], cfg)
    x = x + L.mlp(h, sp["mlp"], cfg, mesh, rules)
    return x, kv


def _hybrid_stack(params, x, cfg, mesh, rules, *, positions, cache=None,
                  decode=False):
    """Zamba2: mamba2 layers + ONE shared attention block invoked every
    cfg.attn_every layers (weights shared; KV caches per invocation slot).

    cache: None (train) or dict(conv (L,...), state (L,...), ak/av
    (n_slots, B, T, K, dh), pos).  `decode` is static."""
    n_layers = cfg.n_layers
    every = max(cfg.attn_every, 1)
    is_attn = jnp.asarray([i % every == 0 for i in range(n_layers)])
    slot_idx = jnp.asarray(np.cumsum([i % every == 0
                                      for i in range(n_layers)]) - 1)
    pos_cache = None if cache is None else cache["pos"]

    def body(carry, xs):
        xc, ak, av = carry
        if cache is None:
            lp, flag, slot = xs
            conv = state = None
        else:
            lp, flag, slot, conv, state = xs

        def with_attn(args):
            xc, ak, av = args
            if cache is None:
                out, _ = _hybrid_shared_block(xc, params, cfg, mesh, rules,
                                              positions=positions)
                return out, ak, av
            kc = jax.lax.dynamic_index_in_dim(ak, slot, 0, keepdims=False)
            vc = jax.lax.dynamic_index_in_dim(av, slot, 0, keepdims=False)
            out, kv = _hybrid_shared_block(
                xc, params, cfg, mesh, rules, positions=positions,
                cache={"k": kc, "v": vc, "pos": pos_cache})
            ak = jax.lax.dynamic_update_index_in_dim(ak, kv["k"], slot, 0)
            av = jax.lax.dynamic_update_index_in_dim(av, kv["v"], slot, 0)
            return out, ak, av

        xc, ak, av = jax.lax.cond(flag, with_attn, lambda a: a, (xc, ak, av))
        if cache is None:
            xc, _ = _ssm_layer(xc, lp, cfg, mesh, rules)
            return (xc, ak, av), None
        xc, sc = _ssm_layer(xc, lp, cfg, mesh, rules,
                            cache={"conv": conv, "state": state,
                                   "decode": decode})
        return (xc, ak, av), sc

    if cache is None:
        body_r = _maybe_remat(body, cfg)
        n_slots = int(np.sum([i % every == 0 for i in range(n_layers)]))
        dummy = jnp.zeros((n_slots, 0), cfg.dtype)   # unused carriers
        (x, _, _), _ = jax.lax.scan(
            body_r, (x, dummy, dummy), (params["layers"], is_attn, slot_idx))
        return x, None
    (x, ak, av), sc = jax.lax.scan(
        body, (x, cache["ak"], cache["av"]),
        (params["layers"], is_attn, slot_idx, cache["conv"], cache["state"]))
    new_cache = {"ak": ak, "av": av, "conv": sc["conv"], "state": sc["state"],
                 "pos": pos_cache + x.shape[1]}
    return x, new_cache


def _encdec_encoder(params, enc_input, cfg, mesh, rules):
    """Whisper encoder over stub frame embeddings (bidirectional)."""
    x = enc_input.astype(cfg.dtype)
    Se = x.shape[1]
    pos = _sinusoidal(Se, cfg.d_model, x.dtype)
    x = x + pos[None]
    positions = jnp.broadcast_to(jnp.arange(Se)[None], x.shape[:2])

    def body(xc, lp):
        h = L.norm(xc, lp["attn_norm"], cfg)
        a, _ = L.attention(h, lp["attn"], cfg, mesh, rules,
                           q_positions=positions, causal=False,
                           use_rope=False)
        xc = xc + a
        h = L.norm(xc, lp["mlp_norm"], cfg)
        xc = xc + L.mlp(h, lp["mlp"], cfg, mesh, rules)
        return xc, None

    body = _maybe_remat(body, cfg)
    enc = params["encoder"]
    x, _ = jax.lax.scan(body, x, enc["layers"])
    return L.norm(x, enc["final_norm"], cfg)


def _encdec_decoder(params, x, enc_out, cfg, mesh, rules, *, positions,
                    cache=None):
    pos_cache = None if cache is None else cache["pos"]

    def layer(xc, lp, c):
        # split cache views: self-attention must never see the cross KV
        self_c = None if c is None else {"k": c["k"], "v": c["v"],
                                         "pos": c["pos"]}
        cross_c = (None if (c is None or "xk" not in c)
                   else {"xk": c["xk"], "xv": c["xv"]})
        h = L.norm(xc, lp["attn_norm"], cfg)
        a, kv = L.attention(h, lp["attn"], cfg, mesh, rules,
                            q_positions=positions, cache=self_c, causal=True,
                            use_rope=False)
        xc = xc + a
        h = L.norm(xc, lp["cross_norm"], cfg)
        a, _ = L.attention(h, lp["cross"], cfg, mesh, rules,
                           x_kv=enc_out, q_positions=positions,
                           cache=cross_c, causal=False, use_rope=False)
        xc = xc + a
        h = L.norm(xc, lp["mlp_norm"], cfg)
        xc = xc + L.mlp(h, lp["mlp"], cfg, mesh, rules)
        return xc, kv

    if cache is None:
        def body(xc, lp):
            out, _ = layer(xc, lp, None)
            return out, None
        body = _maybe_remat(body, cfg)
        x, _ = jax.lax.scan(body, x, params["layers"])
        return x, None

    def body(xc, xs):
        lp, kc, vc, xk, xv = xs
        out, kv = layer(xc, lp, {"k": kc, "v": vc, "pos": pos_cache,
                                 "xk": xk, "xv": xv})
        return out, kv

    x, kvs = jax.lax.scan(
        body, x, (params["layers"], cache["k"], cache["v"],
                  cache["xk"], cache["xv"]))
    new_cache = {"k": kvs["k"], "v": kvs["v"], "xk": cache["xk"],
                 "xv": cache["xv"], "pos": pos_cache + x.shape[1]}
    return x, new_cache


def _sinusoidal(S: int, D: int, dtype) -> jnp.ndarray:
    pos = np.arange(S)[:, None]
    dim = np.arange(D // 2)[None, :]
    ang = pos / (10_000 ** (2 * dim / D))
    out = np.concatenate([np.sin(ang), np.cos(ang)], axis=1)
    return jnp.asarray(out, dtype)


# =====================================================================
# Public entry points
# =====================================================================

def forward(params, batch, cfg: ModelConfig, mesh=None,
            rules: AxisRules | None = None):
    """Full-sequence forward -> logits (B, S_text, V)."""
    tokens = batch["tokens"]
    x = L.embed(tokens, params["embed"]["tokens"], mesh, rules)
    x = x.astype(cfg.dtype)
    prefix_len = 0
    if cfg.family == "vlm":
        vis = batch["vision"].astype(cfg.dtype)         # (B, Nv, D) stub
        x = jnp.concatenate([vis, x], axis=1)
        prefix_len = vis.shape[1]
    B, S = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))

    if cfg.family in ("dense", "moe", "vlm"):
        x, _ = _decoder_stack(params, x, cfg, mesh, rules,
                              positions=positions, prefix_len=prefix_len)
    elif cfg.family == "ssm":
        def body(xc, lp):
            out, _ = _ssm_layer(xc, lp, cfg, mesh, rules)
            return out, None
        x, _ = jax.lax.scan(_maybe_remat(body, cfg), x, params["layers"])
    elif cfg.family == "hybrid":
        x, _ = _hybrid_stack(params, x, cfg, mesh, rules, positions=positions)
    elif cfg.family == "encdec":
        enc_out = _encdec_encoder(params, batch["enc_input"], cfg, mesh, rules)
        x, _ = _encdec_decoder(params, x, enc_out, cfg, mesh, rules,
                               positions=positions)
    else:
        raise ValueError(cfg.family)

    x = L.norm(x, params["final_norm"], cfg)
    if cfg.family == "vlm":
        x = x[:, prefix_len:]                            # logits on text only
    return L.unembed(x, params, cfg, mesh, rules)


def loss_fn(params, batch, cfg: ModelConfig, mesh=None,
            rules: AxisRules | None = None):
    """Next-token cross entropy (labels = batch['labels'], -1 = masked)."""
    logits = forward(params, batch, cfg, mesh, rules).astype(jnp.float32)
    labels = batch["labels"]
    mask = (labels >= 0).astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(
        logits, jnp.maximum(labels, 0)[..., None], axis=-1)[..., 0]
    nll = (logz - gold) * mask
    return nll.sum() / jnp.maximum(mask.sum(), 1.0)


# (cache construction lives in model.py: cache_metas/init_cache)


def prefill(params, batch, cache, cfg: ModelConfig, mesh=None,
            rules: AxisRules | None = None):
    """Run the prompt through the model, filling `cache`.
    Returns (last-position logits (B, V), cache)."""
    tokens = batch["tokens"]
    x = L.embed(tokens, params["embed"]["tokens"], mesh, rules).astype(cfg.dtype)
    prefix_len = 0
    if cfg.family == "vlm":
        vis = batch["vision"].astype(cfg.dtype)
        x = jnp.concatenate([vis, x], axis=1)
        prefix_len = vis.shape[1]
    B, S = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))

    if cfg.family in ("dense", "moe", "vlm"):
        x, cache = _decoder_stack(params, x, cfg, mesh, rules,
                                  positions=positions, cache=cache,
                                  prefix_len=prefix_len)
    elif cfg.family == "ssm":
        pos0 = cache["pos"]

        def body(xc, xs):
            lp, conv, state = xs
            out, sc = _ssm_layer(xc, lp, cfg, mesh, rules,
                                 cache={"conv": conv, "state": state,
                                        "decode": False})
            return out, sc
        x, sc = jax.lax.scan(body, x,
                             (params["layers"], cache["conv"],
                              cache["state"]))
        cache = {"conv": sc["conv"], "state": sc["state"], "pos": pos0 + S}
    elif cfg.family == "hybrid":
        x, cache = _hybrid_stack(params, x, cfg, mesh, rules,
                                 positions=positions, cache=cache,
                                 decode=False)
    elif cfg.family == "encdec":
        enc_out = _encdec_encoder(params, batch["enc_input"], cfg, mesh, rules)
        # precompute cross KV per layer
        cache = dict(cache)
        cache.update(_cross_kv(params, enc_out, cfg, mesh, rules))
        x, cache = _encdec_decoder(params, x, enc_out, cfg, mesh, rules,
                                   positions=positions, cache=cache)
    else:
        raise ValueError(cfg.family)

    x = L.norm(x[:, -1:], params["final_norm"], cfg)
    logits = L.unembed(x, params, cfg, mesh, rules)[:, 0]
    return logits, cache


def _cross_kv(params, enc_out, cfg, mesh, rules):
    K, dh = cfg.n_kv_heads, cfg.head_dim

    def body(_, lp):
        k = (enc_out @ lp["cross"]["wk"].astype(enc_out.dtype)).reshape(
            enc_out.shape[0], enc_out.shape[1], K, dh)
        v = (enc_out @ lp["cross"]["wv"].astype(enc_out.dtype)).reshape(
            enc_out.shape[0], enc_out.shape[1], K, dh)
        return _, {"xk": k, "xv": v}

    _, kv = jax.lax.scan(body, None, params["layers"])
    return kv


def decode_step(params, token, cache, cfg: ModelConfig, mesh=None,
                rules: AxisRules | None = None):
    """One decode step.  token: (B, 1) int32.  Returns (logits (B, V), cache)."""
    x = L.embed(token, params["embed"]["tokens"], mesh, rules).astype(cfg.dtype)
    B = x.shape[0]
    pos = cache["pos"]
    positions = jnp.broadcast_to(pos[None, None], (B, 1)).astype(jnp.int32)

    if cfg.family in ("dense", "moe", "vlm"):
        x, cache = _decoder_stack(params, x, cfg, mesh, rules,
                                  positions=positions, cache=cache)
    elif cfg.family == "ssm":
        pos0 = cache["pos"]

        def body(xc, xs):
            lp, conv, state = xs
            out, sc = _ssm_layer(xc, lp, cfg, mesh, rules,
                                 cache={"conv": conv, "state": state,
                                        "decode": True})
            return out, sc
        x, sc = jax.lax.scan(body, x, (params["layers"], cache["conv"],
                                       cache["state"]))
        cache = {"conv": sc["conv"], "state": sc["state"], "pos": pos0 + 1}
    elif cfg.family == "hybrid":
        x, cache = _hybrid_stack(params, x, cfg, mesh, rules,
                                 positions=positions, cache=cache,
                                 decode=True)
    elif cfg.family == "encdec":
        x, cache = _encdec_decoder(params, x, None, cfg, mesh, rules,
                                   positions=positions, cache=cache)
    else:
        raise ValueError(cfg.family)

    x = L.norm(x, params["final_norm"], cfg)
    logits = L.unembed(x, params, cfg, mesh, rules)[:, 0]
    return logits, cache
