from .config import ModelConfig, ShapeConfig, SHAPES  # noqa: F401
from .model import Model, batch_metas, abstract_batch, concrete_batch  # noqa: F401
from . import layers, moe, ssm, transformer  # noqa: F401
