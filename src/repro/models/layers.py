"""Pure-JAX transformer building blocks shared by every assigned arch.

Conventions
  * Params are nested dicts of arrays; shapes/logical-axes come from the
    ParamMeta trees defined by each family (single source of truth).
  * Attention projections are stored FUSED 2D, (d_model, n_heads*d_head):
    the fused dim is always mesh-divisible even when the head count is not
    (qwen2.5: 40 heads, whisper: 12, paligemma: 8) — activations may shard
    unevenly (GSPMD pads), jit inputs may not.
  * All softmax / norm statistics accumulate in float32.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from ..sharding.rules import AxisRules, constrain
from .config import ModelConfig


# ------------------------------------------------------------------ norms

def rms_norm(x, scale, eps: float):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * scale.astype(jnp.float32)).astype(x.dtype)


def layer_norm(x, scale, bias, eps: float):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (out * scale.astype(jnp.float32)
            + bias.astype(jnp.float32)).astype(x.dtype)


def norm(x, params, cfg: ModelConfig):
    if cfg.norm_type == "layernorm":
        return layer_norm(x, params["scale"], params["bias"], cfg.norm_eps)
    return rms_norm(x, params["scale"], cfg.norm_eps)


# ------------------------------------------------------------------- rope

def rope(x, positions, *, fraction: float = 1.0, theta: float = 10_000.0):
    """Rotary embedding on the leading `fraction` of head dims.

    x: (B, S, H, dh); positions: (B, S) int32.  chatglm3's "2d rope" is the
    fraction=0.5 case (rotary on half the dims, pass-through on the rest).
    """
    dh = x.shape[-1]
    rot = int(dh * fraction)
    rot -= rot % 2
    if rot == 0:
        return x
    x_rot, x_pass = x[..., :rot], x[..., rot:]
    half = rot // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[:, :, None, None] * freq  # (B,S,1,half)
    sin, cos = jnp.sin(ang), jnp.cos(ang)
    x1, x2 = x_rot[..., :half], x_rot[..., half:]
    xr1 = x1 * cos - x2 * sin
    xr2 = x2 * cos + x1 * sin
    out = jnp.concatenate([xr1, xr2, x_pass], axis=-1)
    return out.astype(x.dtype)


# -------------------------------------------------------------- attention

FLASH_THRESHOLD = 2048      # chunk KV when S > 1 and T exceeds this
FLASH_KV_CHUNK = 512


def _mask_block(q_positions, t_idx, kv_valid_len, causal, prefix_len, B, S):
    """(B,1,1,S,c) boolean allowed-mask for a KV block at absolute t_idx."""
    ok = jnp.ones((B, 1, 1, S, t_idx.shape[0]), bool)
    t = t_idx[None, None, None, None, :]
    if causal:
        qp = q_positions[:, None, None, :, None]
        ok &= (t <= qp) | (t < prefix_len)
    if kv_valid_len is not None:
        ok &= t < kv_valid_len[:, None, None, None, None]
    return ok


def attn_core(
    q, k, v, *,
    q_positions, kv_valid_len=None, causal=True, prefix_len=0,
):
    """Grouped-query attention core.

    q: (B, S, H, dh); k, v: (B, T, K, dh) with H = K * G.  Never materializes
    repeated KV (decode caches stay K-headed); logits are computed in the
    (K, G) factored form and fp32.

    Long sequences (S > 1 and T > FLASH_THRESHOLD) take a flash-style
    KV-chunked path (lax.scan with running max/sum/acc) so the (S, T)
    logits tensor never materializes — mandatory at prefill_32k scale.

    q_positions: (B, S) absolute positions of the queries.
    kv_valid_len: (B,) or None — number of valid cache rows (T laid out
      from absolute position 0).
    prefix_len: bidirectional prefix (PaliGemma prefix-LM).
    """
    B, S, H, dh = q.shape
    T, K = k.shape[1], k.shape[2]
    G = H // K
    qf = q.reshape(B, S, K, G, dh).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    scale = 1.0 / np.sqrt(dh)

    if S > 1 and T > FLASH_THRESHOLD and T % FLASH_KV_CHUNK == 0:
        c = FLASH_KV_CHUNK

        def body(carry, ci):
            m, l, acc = carry
            ks = jax.lax.dynamic_slice_in_dim(kf, ci * c, c, axis=1)
            vs = jax.lax.dynamic_slice_in_dim(vf, ci * c, c, axis=1)
            logits = jnp.einsum("bskgd,btkd->bkgst", qf, ks) * scale
            t_idx = ci * c + jnp.arange(c)
            ok = _mask_block(q_positions, t_idx, kv_valid_len, causal,
                             prefix_len, B, S)              # (B,1,1,S,c)
            logits = jnp.where(ok, logits, -1e30)
            m_new = jnp.maximum(m, logits.max(-1))
            p = jnp.exp(logits - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bkgst,btkd->bkgsd", p, vs)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, K, G, S), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((B, K, G, S), jnp.float32)
        a0 = jnp.zeros((B, K, G, S, dh), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0),
                                      jnp.arange(T // c))
        out = acc / jnp.maximum(l, 1e-30)[..., None]         # (B,K,G,S,dh)
        out = jnp.moveaxis(out, 3, 1).reshape(B, S, H, dh)
        return out.astype(q.dtype)

    logits = jnp.einsum("bskgd,btkd->bkgst", qf, kf) * scale
    t_idx = jnp.arange(T)
    ok = _mask_block(q_positions, t_idx, kv_valid_len, causal, prefix_len,
                     B, S)                                   # (B,1,1,S,T)
    logits = jnp.where(ok, logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgst,btkd->bskgd", probs, vf)
    return out.reshape(B, S, H, dh).astype(q.dtype)


def attention(
    x_q, params, cfg: ModelConfig, mesh, rules: AxisRules, *,
    x_kv=None,                 # cross attention source (whisper decoder)
    q_positions,               # (B, S)
    cache=None,                # dict(k=(B,T,K,dh), v=..., pos scalar) or None
    causal=True,
    prefix_len=0,
    use_rope=True,
):
    """Full attention block body (no residual / pre-norm — caller owns).

    Returns (out (B,S,D), new_cache_kv or None).
    """
    B, S, D = x_q.shape
    H, dh = cfg.n_heads, cfg.head_dim
    K = cfg.n_kv_heads
    x_kv_in = x_q if x_kv is None else x_kv

    def proj(x, w, b, n):
        y = x @ w.astype(x.dtype)
        if b is not None:
            y = y + b.astype(x.dtype)
        y = constrain(y, mesh, rules, "act_batch", None, "act_heads")
        return y.reshape(x.shape[0], x.shape[1], n, dh)

    q = proj(x_q, params["wq"], params.get("bq"), H)
    new_cache = None
    if cache is not None and "xk" in cache:
        # cross-attention with precomputed encoder KV (x_kv may be None
        # during decode — the encoder output is only needed at prefill)
        k, v = cache["xk"], cache["xv"]
    else:
        k = proj(x_kv_in, params["wk"], params.get("bk"), K)
        v = proj(x_kv_in, params["wv"], params.get("bv"), K)

    if cfg.qk_norm:  # qwen3: per-head RMSNorm before rope
        q = rms_norm(q, params["q_norm"], cfg.norm_eps)
        k = rms_norm(k, params["k_norm"], cfg.norm_eps)

    if use_rope and (x_kv is None):
        # new K rows share the query positions (contiguous decode/prefill)
        q = rope(q, q_positions, fraction=cfg.rope_fraction,
                 theta=cfg.rope_theta)
        k = rope(k, q_positions, fraction=cfg.rope_fraction,
                 theta=cfg.rope_theta)

    kv_valid = None
    if cache is not None and "k" in cache:
        # self-attention cache: write new K/V at position `pos`
        pos = cache["pos"]
        ck = jax.lax.dynamic_update_slice_in_dim(cache["k"],
                                                 k.astype(cache["k"].dtype),
                                                 pos, axis=1)
        cv = jax.lax.dynamic_update_slice_in_dim(cache["v"],
                                                 v.astype(cache["v"].dtype),
                                                 pos, axis=1)
        k, v = ck, cv
        kv_valid = jnp.full((B,), pos + S, jnp.int32)
        new_cache = {"k": ck, "v": cv}

    out = attn_core(q, k, v, q_positions=q_positions, kv_valid_len=kv_valid,
                    causal=causal, prefix_len=prefix_len)
    out = out.reshape(B, S, H * dh)
    out = constrain(out, mesh, rules, "act_batch", None, "act_heads")
    y = out @ params["wo"].astype(out.dtype)
    if params.get("bo") is not None:
        y = y + params["bo"].astype(y.dtype)
    return constrain(y, mesh, rules, "act_batch", None, None), new_cache


# -------------------------------------------------------------------- mlp

def mlp(x, params, cfg: ModelConfig, mesh, rules: AxisRules):
    if cfg.mlp_type == "swiglu":
        g = x @ params["wg"].astype(x.dtype)
        u = x @ params["wu"].astype(x.dtype)
        h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    elif cfg.mlp_type == "squared_relu":     # nemotron-4
        h = x @ params["wi"].astype(x.dtype)
        h = jnp.square(jax.nn.relu(h.astype(jnp.float32))).astype(x.dtype)
    elif cfg.mlp_type == "gelu":             # whisper
        h = x @ params["wi"].astype(x.dtype)
        h = jax.nn.gelu(h.astype(jnp.float32)).astype(x.dtype)
    else:
        raise ValueError(cfg.mlp_type)
    h = constrain(h, mesh, rules, "act_batch", None, "act_ff")
    y = h @ params["wo"].astype(x.dtype)
    return constrain(y, mesh, rules, "act_batch", None, None)


# -------------------------------------------------------------- embedding

def embed(tokens, table, mesh, rules):
    y = jnp.take(table, tokens, axis=0)
    return constrain(y, mesh, rules, "act_batch", None, None)


def unembed(x, params, cfg: ModelConfig, mesh, rules):
    if cfg.tie_embeddings:
        w = params["embed"]["tokens"]
        logits = x @ w.astype(x.dtype).T
    else:
        logits = x @ params["unembed"]["kernel"].astype(x.dtype)
    return constrain(logits, mesh, rules, "act_batch", None, "act_vocab")
