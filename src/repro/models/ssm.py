"""Mamba2 (SSD — state-space duality, arXiv:2405.21060) block.

Training path: chunked SSD — within-chunk quadratic "attention" term plus
an inter-chunk state recurrence (lax.scan over chunks).  Decode path: O(1)
recurrent state update (B, H, P, N), no KV growth — this is why the ssm /
hybrid archs are the `long_500k` cells.

Layout: x (B, S, D) -> z, xs (B, S, dI), B/C (B, S, G, N), dt (B, S, H);
depthwise causal conv over [xs, B, C]; heads H = dI / P.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..sharding.rules import AxisRules, constrain
from .config import ModelConfig
from .layers import rms_norm


def _segsum(dA):
    """dA: (..., q) -> (..., q, q) with out[i, j] = sum_{j < m <= i} dA[m],
    -inf above the diagonal (exp -> lower-triangular decay matrix)."""
    q = dA.shape[-1]
    csum = jnp.cumsum(dA, axis=-1)
    diff = csum[..., :, None] - csum[..., None, :]
    mask = jnp.tril(jnp.ones((q, q), bool))
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(xh, dt, A, Bm, Cm, chunk: int):
    """Chunked SSD scan.

    xh: (B, S, H, P) head inputs;   dt: (B, S, H) positive step sizes
    A:  (H,) negative decay rates;  Bm, Cm: (B, S, H, N) (head-expanded)
    Returns (y (B, S, H, P), final_state (B, H, P, N)).
    """
    Bsz, S, H, P = xh.shape
    N = Bm.shape[-1]
    chunk = min(chunk, S)
    nc = S // chunk
    assert nc * chunk == S, f"seq {S} must be a multiple of chunk {chunk}"

    f32 = jnp.float32
    dA = (dt * A).astype(f32)                                   # (B,S,H)
    xdt = (xh * dt[..., None]).astype(f32)                      # dt-scaled in

    def c(t, extra=()):        # (B, S, ...) -> (B, nc, chunk, ...)
        return t.reshape((Bsz, nc, chunk) + t.shape[2:])

    dA_c = c(dA).transpose(0, 3, 1, 2)                          # (B,H,nc,q)
    x_c, B_c, C_c = c(xdt), c(Bm.astype(f32)), c(Cm.astype(f32))

    # 1. within-chunk (quadratic) term
    L = jnp.exp(_segsum(dA_c))                                  # (B,H,nc,q,q)
    y_diag = jnp.einsum("bcqhn,bckhn,bhcqk,bckhp->bcqhp",
                        C_c, B_c, L, x_c)

    # 2. per-chunk states
    dA_cs = jnp.cumsum(dA_c, axis=-1)                           # (B,H,nc,q)
    decay_in = jnp.exp(dA_cs[..., -1:] - dA_cs)                 # (B,H,nc,q)
    states = jnp.einsum("bckhn,bhck,bckhp->bchpn", B_c, decay_in, x_c)

    # 3. inter-chunk recurrence
    chunk_decay = jnp.exp(dA_cs[..., -1])                       # (B,H,nc)

    def step(carry, inp):
        s_c, d_c = inp                                          # (B,H,P,N),(B,H)
        prev = carry
        new = prev * d_c[..., None, None] + s_c
        return new, prev

    s0 = jnp.zeros((Bsz, H, P, N), f32)
    final, prev_states = jax.lax.scan(
        step, s0,
        (states.transpose(1, 0, 2, 3, 4),                      # (nc,B,H,P,N)
         chunk_decay.transpose(2, 0, 1)))                      # (nc,B,H)
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)         # (B,nc,H,P,N)

    # 4. state -> output contribution
    out_decay = jnp.exp(dA_cs)                                 # (B,H,nc,q)
    y_off = jnp.einsum("bcqhn,bchpn,bhcq->bcqhp",
                       C_c, prev_states, out_decay)

    y = (y_diag + y_off).reshape(Bsz, S, H, P)
    return y.astype(xh.dtype), final


def _split_proj(x, params, cfg: ModelConfig):
    dI = cfg.d_inner
    GN = cfg.ssm_groups * cfg.ssm_state
    z = x @ params["wz"].astype(x.dtype)                        # (B,S,dI)
    xs = x @ params["wx"].astype(x.dtype)                       # (B,S,dI)
    Bp = x @ params["wb"].astype(x.dtype)                       # (B,S,GN)
    Cp = x @ params["wc"].astype(x.dtype)                       # (B,S,GN)
    dt = x @ params["wdt"].astype(x.dtype)                      # (B,S,H)
    return z, jnp.concatenate([xs, Bp, Cp], axis=-1), dt, dI, GN


def _conv_apply(conv_in, kernel, *, conv_state=None):
    """Depthwise causal conv1d.  conv_in: (B, S, Cd); kernel: (kw, Cd).

    Train: left-pad.  Decode (S==1): use/update the (B, kw-1, Cd) state.
    Returns (out, new_state or None)."""
    kw = kernel.shape[0]
    if conv_state is None:
        pad = jnp.pad(conv_in, ((0, 0), (kw - 1, 0), (0, 0)))
        new_state = None
    else:
        pad = jnp.concatenate([conv_state.astype(conv_in.dtype), conv_in], 1)
        new_state = pad[:, -(kw - 1):, :]
    out = sum(pad[:, i:i + conv_in.shape[1], :] * kernel[i][None, None, :]
              for i in range(kw))
    return jax.nn.silu(out.astype(jnp.float32)).astype(conv_in.dtype), new_state


def _heads(cfg, conv_out, dI, GN):
    B, S, _ = conv_out.shape
    H, P, G, N = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_groups, cfg.ssm_state
    xs = conv_out[..., :dI].reshape(B, S, H, P)
    Bm = conv_out[..., dI:dI + GN].reshape(B, S, G, N)
    Cm = conv_out[..., dI + GN:].reshape(B, S, G, N)
    rep = H // G
    Bm = jnp.repeat(Bm, rep, axis=2)                            # (B,S,H,N)
    Cm = jnp.repeat(Cm, rep, axis=2)
    return xs, Bm, Cm


def mamba_block(x, params, cfg: ModelConfig, mesh, rules: AxisRules,
                chunk: int = 128):
    """Training/prefill forward.  Returns (y (B,S,D), cache dict)."""
    z, conv_in, dt, dI, GN = _split_proj(x, params, cfg)
    conv_in = constrain(conv_in, mesh, rules, "act_batch", None, "act_ssm")
    conv_out, _ = _conv_apply(conv_in, params["conv"])
    xs, Bm, Cm = _heads(cfg, conv_out, dI, GN)
    A = -jnp.exp(params["a_log"].astype(jnp.float32))           # (H,)
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + params["dt_bias"].astype(jnp.float32))
    y, state = ssd_chunked(xs, dt, A, Bm, Cm, chunk)
    y = y + xs * params["d_skip"].astype(x.dtype)[None, None, :, None]
    y = y.reshape(x.shape[0], x.shape[1], dI)
    y = constrain(y, mesh, rules, "act_batch", None, "act_ssm")
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype),
                 params["norm_scale"], cfg.norm_eps)
    out = y @ params["wo"].astype(x.dtype)
    kw = params["conv"].shape[0]
    conv_state = jnp.concatenate(
        [jnp.zeros((x.shape[0], kw - 1, conv_in.shape[-1]), conv_in.dtype),
         conv_in], axis=1)[:, -(kw - 1):, :]
    cache = {"state": state, "conv": conv_state}
    return constrain(out, mesh, rules, "act_batch", None, None), cache


def mamba_decode_step(x, params, cfg: ModelConfig, mesh, rules: AxisRules,
                      cache):
    """Single-token decode.  x: (B, 1, D); cache: state (B,H,P,N) f32,
    conv (B, kw-1, conv_dim)."""
    z, conv_in, dt, dI, GN = _split_proj(x, params, cfg)
    conv_out, new_conv = _conv_apply(conv_in, params["conv"],
                                     conv_state=cache["conv"])
    xs, Bm, Cm = _heads(cfg, conv_out, dI, GN)                  # S=1
    A = -jnp.exp(params["a_log"].astype(jnp.float32))
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + params["dt_bias"].astype(jnp.float32))[:, 0]  # (B,H)
    xh = xs[:, 0].astype(jnp.float32)                           # (B,H,P)
    Bh = Bm[:, 0].astype(jnp.float32)                           # (B,H,N)
    Ch = Cm[:, 0].astype(jnp.float32)
    state = cache["state"]
    state = constrain(state, mesh, rules, "cache_batch", "state_heads",
                      None, None)
    dA = jnp.exp(dt * A)                                        # (B,H)
    state = state * dA[..., None, None] + jnp.einsum(
        "bhn,bhp->bhpn", Bh, xh * dt[..., None])
    yh = jnp.einsum("bhn,bhpn->bhp", Ch, state)
    yh = yh + xh * params["d_skip"].astype(jnp.float32)[:, None]
    y = yh.reshape(x.shape[0], 1, dI).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype),
                 params["norm_scale"], cfg.norm_eps)
    out = y @ params["wo"].astype(x.dtype)
    return out, {"state": state, "conv": new_conv}
