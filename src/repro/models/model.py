"""Public model API: a thin façade over transformer.py keyed by config,
plus batch/cache ShapeDtypeStruct + PartitionSpec builders used by the
trainer, the server and the multi-pod dry-run."""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from ..sharding.rules import (AxisRules, ParamMeta, param_pspecs,
                              resolve_spec)
from . import transformer as T
from .config import ModelConfig, ShapeConfig


# ------------------------------------------------------------ batch metas

def batch_metas(cfg: ModelConfig, sc: ShapeConfig) -> dict[str, ParamMeta]:
    """Input tensors for one step of the given shape cell."""
    B, S = sc.global_batch, sc.seq_len
    out: dict[str, ParamMeta] = {}
    if sc.kind == "train":
        s_text = S - (cfg.n_vision_tokens if cfg.family == "vlm" else 0)
        out["tokens"] = ParamMeta((B, s_text), ("act_batch", None), "int32")
        out["labels"] = ParamMeta((B, s_text), ("act_batch", None), "int32")
    elif sc.kind == "prefill":
        s_text = S - (cfg.n_vision_tokens if cfg.family == "vlm" else 0)
        out["tokens"] = ParamMeta((B, s_text), ("act_batch", None), "int32")
    else:                                    # decode: one new token
        out["tokens"] = ParamMeta((B, 1), ("act_batch", None), "int32")
    if cfg.family == "vlm" and sc.kind != "decode":
        out["vision"] = ParamMeta((B, cfg.n_vision_tokens, cfg.d_model),
                                  ("act_batch", None, None), cfg.dtype)
    if cfg.family == "encdec" and sc.kind != "decode":
        out["enc_input"] = ParamMeta((B, cfg.enc_seq_len, cfg.d_model),
                                     ("act_batch", None, None), cfg.dtype)
    return out


def abstract_batch(cfg: ModelConfig, sc: ShapeConfig):
    return {k: jax.ShapeDtypeStruct(m.shape, np.dtype(m.dtype))
            for k, m in batch_metas(cfg, sc).items()}


def concrete_batch(cfg: ModelConfig, sc: ShapeConfig, key):
    out = {}
    for name, m in batch_metas(cfg, sc).items():
        key, sub = jax.random.split(key)
        if np.dtype(m.dtype) == np.int32:
            out[name] = jax.random.randint(sub, m.shape, 0, cfg.vocab_size,
                                           jnp.int32)
        else:
            out[name] = jax.random.normal(sub, m.shape, jnp.float32) \
                .astype(m.dtype)
    return out


def batch_pspecs(cfg: ModelConfig, sc: ShapeConfig, mesh, rules: AxisRules):
    return {k: resolve_spec(mesh, rules, m.axes, m.shape, strict=True)
            for k, m in batch_metas(cfg, sc).items()}


# ------------------------------------------------------------ cache metas

def cache_metas(cfg: ModelConfig, B: int, T_max: int,
                enc_len: int | None = None) -> dict:
    dt = cfg.dtype
    K, dh, Ls = cfg.n_kv_heads, cfg.head_dim, cfg.n_layers
    kv_axes = (None, "cache_batch", "cache_seq", None, None)
    out: dict[str, Any] = {"pos": ParamMeta((), (), "int32")}
    if cfg.family in ("dense", "moe", "vlm", "encdec"):
        out["k"] = ParamMeta((Ls, B, T_max, K, dh), kv_axes, dt)
        out["v"] = ParamMeta((Ls, B, T_max, K, dh), kv_axes, dt)
    if cfg.family == "encdec":
        Se = enc_len or cfg.enc_seq_len
        xa = (None, "cache_batch", None, None, None)
        out["xk"] = ParamMeta((Ls, B, Se, K, dh), xa, dt)
        out["xv"] = ParamMeta((Ls, B, Se, K, dh), xa, dt)
    if cfg.family in ("ssm", "hybrid"):
        conv_d = cfg.d_inner + 2 * cfg.ssm_groups * cfg.ssm_state
        out["conv"] = ParamMeta((Ls, B, cfg.ssm_conv - 1, conv_d),
                                (None, "cache_batch", None, "conv_dim"), dt)
        out["state"] = ParamMeta(
            (Ls, B, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state),
            (None, "cache_batch", "state_heads", None, None), "float32")
    if cfg.family == "hybrid":
        every = max(cfg.attn_every, 1)
        n_slots = int(np.sum([i % every == 0 for i in range(Ls)]))
        out["ak"] = ParamMeta((n_slots, B, T_max, K, dh), kv_axes, dt)
        out["av"] = ParamMeta((n_slots, B, T_max, K, dh), kv_axes, dt)
    return out


def cache_pspecs(cfg, B, T_max, mesh, rules, enc_len=None):
    return jax.tree.map(
        lambda m: resolve_spec(mesh, rules, m.axes, m.shape, strict=True),
        cache_metas(cfg, B, T_max, enc_len),
        is_leaf=lambda m: isinstance(m, ParamMeta))


# ------------------------------------------------------------------ model

@dataclasses.dataclass
class Model:
    cfg: ModelConfig

    # params
    def param_metas(self):
        return T.param_metas(self.cfg)

    def init(self, key):
        return T.init_params(self.cfg, key)

    def abstract_params(self):
        return jax.tree.map(
            lambda m: jax.ShapeDtypeStruct(m.shape, np.dtype(m.dtype)),
            self.param_metas(),
            is_leaf=lambda m: isinstance(m, ParamMeta))

    def param_specs(self, mesh, rules: AxisRules):
        return param_pspecs(self.param_metas(), mesh, rules)

    def n_params(self) -> int:
        metas = jax.tree.leaves(
            self.param_metas(),
            is_leaf=lambda m: isinstance(m, ParamMeta))
        return int(sum(np.prod(m.shape) for m in metas))

    def n_active_params(self) -> int:
        """MoE: parameters touched per token (top-k of E experts)."""
        cfg = self.cfg
        if cfg.family != "moe":
            return self.n_params()
        total = 0
        flat = jax.tree_util.tree_flatten_with_path(
            self.param_metas(),
            is_leaf=lambda m: isinstance(m, ParamMeta))[0]
        for path, m in flat:
            size = int(np.prod(m.shape))
            names = [getattr(p, "key", "") for p in path]
            if any(n in ("wg", "wu", "wo") for n in names) and \
               "mlp" in names and len(m.shape) == 4:
                size = size * cfg.experts_per_token // cfg.n_experts
            total += size
        return total

    # compute
    def forward(self, params, batch, mesh=None, rules=None):
        return T.forward(params, batch, self.cfg, mesh, rules)

    def loss(self, params, batch, mesh=None, rules=None):
        return T.loss_fn(params, batch, self.cfg, mesh, rules)

    def init_cache(self, B, T_max, abstract=False, enc_len=None):
        metas = cache_metas(self.cfg, B, T_max, enc_len)
        def mk(m):
            if abstract:
                return jax.ShapeDtypeStruct(m.shape, np.dtype(m.dtype))
            return jnp.zeros(m.shape, np.dtype(m.dtype))
        return jax.tree.map(mk, metas,
                            is_leaf=lambda m: isinstance(m, ParamMeta))

    def prefill(self, params, batch, cache, mesh=None, rules=None):
        return T.prefill(params, batch, cache, self.cfg, mesh, rules)

    def decode_step(self, params, token, cache, mesh=None, rules=None):
        return T.decode_step(params, token, cache, self.cfg, mesh, rules)
