"""Token-choice top-k Mixture-of-Experts with sort-based capacity dispatch.

Shardable design (EP over the `model` mesh axis when the expert count
divides it — kimi-k2's 384 experts; grok-1's 8 experts fall back to
per-expert tensor parallelism on d_ff, see sharding rules):

  router -> top-k -> flatten (T*k assignments) -> argsort by expert ->
  rank-within-expert -> capacity-bounded slots -> gather into an
  (E, C, D) dispatch buffer -> per-expert batched matmul -> weighted
  scatter-add back to tokens.

Memory is O(T * k * D) for the dispatch buffer (inherent to top-k routing),
which is why MoE train configs run with gradient accumulation
(see repro.training.train_loop).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..sharding.rules import AxisRules, constrain
from .config import ModelConfig


def capacity(cfg: ModelConfig, n_tokens: int) -> int:
    """Static per-expert capacity: cf * T * k / E, floored at 4."""
    c = int(cfg.moe_capacity_factor * n_tokens * cfg.experts_per_token
            / cfg.n_experts)
    return max(4, c)


def moe_block(x, params, cfg: ModelConfig, mesh, rules: AxisRules):
    """x: (B, S, D) -> (B, S, D); params: router (D,E), wg/wu (E,D,F),
    wo (E,F,D)."""
    B, S, D = x.shape
    T = B * S
    E, k = cfg.n_experts, cfg.experts_per_token
    C = capacity(cfg, T)
    xf = x.reshape(T, D)

    # ---- routing (fp32)
    logits = (xf.astype(jnp.float32)
              @ params["router"].astype(jnp.float32))           # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    w, sel = jax.lax.top_k(probs, k)                            # (T, k)
    w = w / (w.sum(-1, keepdims=True) + 1e-9)

    # ---- sort assignments by expert
    flat_e = sel.reshape(-1)                                    # (T*k,)
    flat_t = jnp.repeat(jnp.arange(T), k)
    flat_w = w.reshape(-1)
    order = jnp.argsort(flat_e)
    se, st, sw = flat_e[order], flat_t[order], flat_w[order]

    # rank of each assignment within its expert's group
    counts = jnp.bincount(se, length=E)                         # (E,)
    seg_start = jnp.cumsum(counts) - counts
    rank = jnp.arange(T * k) - seg_start[se]
    keep = rank < C
    slot = jnp.where(keep, se * C + rank, E * C)                # E*C = drop bin

    # ---- dispatch via INVERSE-PERMUTATION GATHERS.
    # A direct (T*k, D).at[slot].set scatter makes GSPMD replicate the
    # update tensor per device (~100 GB at kimi-k2 scale).  Instead we
    # scatter only int32 indices (tiny) to build slot->source maps, then
    # move activations with gathers, which GSPMD shards (EXPERIMENTS.md
    # §Perf, kimi hillclimb iteration 1).
    inv = jnp.full((E * C + 1,), T * k, jnp.int32)              # drop bin
    inv = inv.at[slot].set(jnp.arange(T * k, dtype=jnp.int32))
    inv = inv[:-1]                                              # (E*C,)
    valid = (inv < T * k)
    src_tok = jnp.where(valid, st[jnp.minimum(inv, T * k - 1)], 0)
    h_in = xf[src_tok] * valid[:, None].astype(x.dtype)         # (E*C, D)
    h_in = h_in.reshape(E, C, D)
    h_in = constrain(h_in, mesh, rules, "act_expert", "act_batch", None)

    # ---- per-expert ffn (swiglu)
    g = jnp.einsum("ecd,edf->ecf", h_in, params["wg"].astype(x.dtype))
    u = jnp.einsum("ecd,edf->ecf", h_in, params["wu"].astype(x.dtype))
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    h = constrain(h, mesh, rules, "act_expert", "act_batch", "act_ff")
    out = jnp.einsum("ecf,efd->ecd", h, params["wo"].astype(x.dtype))
    out = constrain(out, mesh, rules, "act_expert", "act_batch", None)

    # ---- combine: gather each token's k contributions (no scatter-add)
    contrib = out.reshape(E * C, D)
    contrib = constrain(contrib, mesh, rules, "act_batch", None)
    # slot of the j-th assignment of token t, in original (t, j) order
    rank_of_flat = jnp.argsort(order)                           # (T*k,)
    slot_of_flat = slot[rank_of_flat]
    w_of_flat = (flat_w * keep[rank_of_flat]).astype(x.dtype)
    picked = contrib[jnp.minimum(slot_of_flat, E * C - 1)]      # (T*k, D)
    picked = jnp.where((slot_of_flat < E * C)[:, None], picked, 0.0)
    picked = constrain(picked, mesh, rules, "act_batch", None)
    y = (picked * w_of_flat[:, None]).reshape(T, k, D).sum(axis=1)
    y = y.reshape(B, S, D)
    return constrain(y, mesh, rules, "act_batch", None, None)


def aux_load_balance_loss(x, params, cfg: ModelConfig) -> jnp.ndarray:
    """Switch-style load-balance auxiliary loss (fraction * prob per expert)."""
    T = x.shape[0] * x.shape[1]
    logits = (x.reshape(T, -1).astype(jnp.float32)
              @ params["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, -1)
    sel = jnp.argmax(probs, -1)
    frac = jnp.mean(jax.nn.one_hot(sel, cfg.n_experts), axis=0)
    mean_p = jnp.mean(probs, axis=0)
    return cfg.n_experts * jnp.sum(frac * mean_p)
