"""End-to-end training driver example: train a ~10-100M-param LM for a few
hundred steps with checkpointing and a mid-run injected failure (the
resilient runner recovers and finishes).

  PYTHONPATH=src python examples/train_lm.py [--steps 200]
"""

import argparse
import tempfile

from repro.launch import train as train_mod


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--arch", default="qwen3-1.7b")
    args = ap.parse_args()

    with tempfile.TemporaryDirectory() as ckpt:
        final_loss, losses = train_mod.main([
            "--arch", args.arch, "--scale", "smoke",
            "--steps", str(args.steps), "--batch", "8", "--seq", "64",
            "--ckpt-dir", ckpt, "--ckpt-every", "50",
            "--inject-failure-at", str(args.steps // 2),
        ])
    assert losses[-1] < losses[0], (losses[0], losses[-1])
    print(f"OK: loss {losses[0]:.3f} -> {losses[-1]:.3f} "
          f"with one injected failure recovered")


if __name__ == "__main__":
    main()
