"""RAG / kNN-LM bridge: the paper's PP-ANNS as a first-class serving
feature of the LM stack, through the public API (`repro.api`,
DESIGN.md §9).

An LM server decodes while a privacy-preserving retrieval sidecar — a
keyless `SecureAnnService` over the unified batched search engine
(DESIGN.md §2) — serves k-NN over an *encrypted* embedding datastore
(kNN-LM style: the datastore maps context embeddings -> next tokens;
retrieved neighbors' targets blend with the LM logits).  Each decode
step issues the whole batch of queries as ONE `SearchRequest`; the
cloud host of the datastore never sees embeddings, queries, or
distances — only DCE comparison signs.

  PYTHONPATH=src python examples/rag_serving.py
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import (DataOwnerClient, IndexSpec, SearchParams,
                       SecureAnnService)
from repro.configs import get_config
from repro.models import Model
from repro.serving import LMServer


def main():
    cfg = dataclasses.replace(get_config("qwen3-1.7b").smoke(), remat=False)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    server = LMServer(model, params)
    rng = np.random.default_rng(0)

    # ---- build an encrypted kNN-LM datastore: (embedding, next-token)
    print("building encrypted kNN-LM datastore ...")
    n_store, d = 4000, cfg.d_model
    store_emb = rng.standard_normal((n_store, d)).astype(np.float32)
    store_tok = rng.integers(0, cfg.vocab_size, n_store).astype(np.int32)

    spec = IndexSpec(tenant="lm", name="datastore", d=d, backend="flat",
                     sap_beta=1.0, seed=1)
    owner = DataOwnerClient(spec)              # keys stay with the owner
    svc = SecureAnnService()
    svc.create_collection(spec, corpus=None)
    svc.insert("lm", "datastore", *owner.encrypt_vectors(store_emb))
    user = owner.query_client()

    # ---- decode with secure retrieval at each step
    B, k, lam = 2, 8, 0.3
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, 16), 0,
                              cfg.vocab_size, jnp.int32)
    cache = model.init_cache(B, 64)
    logits, cache = model.prefill(params, {"tokens": toks}, cache)

    generated = []
    for step in range(8):
        # query the encrypted datastore with the *current* hidden summary
        # (here: the embedding row of the argmax token as a cheap proxy)
        probe = np.asarray(
            jnp.take(params["embed"]["tokens"],
                     jnp.argmax(logits, -1), axis=0), np.float32)
        req = user.request("lm", "datastore", probe,
                           SearchParams(k=k))          # one batch request
        nbr = svc.submit(req).ids                                    # (B, k)
        knn_tokens = store_tok[nbr]                                  # (B, k)

        # kNN-LM blend: boost retrieved tokens' logits
        knn_logits = np.full(logits.shape, -1e30, np.float32)
        for b in range(B):
            for t in knn_tokens[b]:
                knn_logits[b, t] = 0.0
        blended = (1 - lam) * np.asarray(logits) + lam * knn_logits
        nxt = jnp.asarray(blended.argmax(-1).astype(np.int32))[:, None]
        generated.append(nxt)
        logits, cache = model.decode_step(params, nxt, cache)

    out = jnp.concatenate(generated, 1)
    svc.close()
    print(f"decoded {out.shape} tokens with privacy-preserving retrieval "
          f"at every step (datastore host saw only ciphertexts)")
    assert out.shape == (B, 8)
    print("OK")


if __name__ == "__main__":
    main()
