"""Quickstart: the paper's full pipeline through the public API
(`repro.api`, DESIGN.md §9) in ~60 lines.

  1. Data owner: keygen from an `IndexSpec`, encrypts the database
     (DCPE filter + DCE refine ciphertexts), builds the privacy-
     preserving HNSW index, and outsources it as an `EncryptedCorpus`.
  2. User: encrypts each query (DCPE ciphertext + DCE trapdoor) into an
     `EncryptedQuery` with the shared keys.
  3. Service: answers k-ANN over ciphertexts only (filter-and-refine,
     Algorithm 2) — and we check recall against exact brute force.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.api import (DataOwnerClient, IndexSpec, SearchParams,
                       SecureAnnService, suggest_beta)
from repro.data import synth


def main():
    print("== PP-ANNS quickstart ==")
    ds = synth.make_dataset("sift1m", n=5000, n_queries=25, k_gt=50, seed=0)
    print(f"dataset: n={ds.n} d={ds.d} (clustered synthetic, SIFT dims)")

    print("data owner: encrypting database + building DCPE-HNSW index ...")
    spec = IndexSpec(tenant="demo", name="corpus", d=ds.d, backend="hnsw",
                     sap_beta=suggest_beta(ds.base, fraction=0.03),
                     hnsw_M=16, hnsw_ef_construction=120, seed=7)
    owner = DataOwnerClient(spec)               # keygen — keys stay here
    corpus = owner.encrypt_corpus(ds.base)      # ciphertexts + HNSW graph
    print(f"  DCPE ciphertexts: {corpus.C_sap.shape}  "
          f"DCE ciphertexts: {corpus.C_dce.shape}")

    k = 10
    params = SearchParams(k=k, ratio_k=8, ef_search=128)
    with SecureAnnService() as svc:
        svc.create_collection(spec, corpus=corpus)   # server: ciphertexts only
        user = owner.query_client()                  # trusted key handoff

        found, lat = [], []
        for q in ds.queries:
            req = user.request(spec.tenant, spec.name, q, params)
            res = svc.submit(req)                    # server-side Algorithm 2
            found.append(res.ids[0])
            lat.append(res.stats.latency_s)
        rec = synth.recall_at_k(np.stack(found), ds.gt, k)
        print(f"service-side search: recall@{k} = {rec:.3f}, "
              f"median latency {1e3 * np.median(lat):.1f} ms, "
              f"QPS ~ {1.0 / np.median(lat):.1f}")

        # what the service never sees: plaintexts, keys, or distances
        res = svc.submit(user.request(spec.tenant, spec.name,
                                      ds.queries[0], params))
        print(f"bytes up per query: {res.stats.bytes_up} (O(d)); "
              f"bytes down: {res.stats.bytes_down} (8 bytes per int64 id)")
        print(f"refine comparisons: {res.stats.refine_comparisons} "
              f"(each leaks only a sign, Theorem 3)")
    assert rec >= 0.85
    print("OK")


if __name__ == "__main__":
    main()
