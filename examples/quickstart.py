"""Quickstart: the paper's full pipeline in ~60 lines.

  1. Data owner encrypts a vector database (DCPE filter ciphertexts +
     DCE refine ciphertexts) and builds the privacy-preserving HNSW index.
  2. User encrypts a query (DCPE ciphertext + DCE trapdoor).
  3. Server answers k-ANN over ciphertexts only (filter-and-refine,
     Algorithm 2) — and we check recall against exact brute force.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core import ppanns
from repro.data import synth


def main():
    print("== PP-ANNS quickstart ==")
    ds = synth.make_dataset("sift1m", n=5000, n_queries=25, k_gt=50, seed=0)
    print(f"dataset: n={ds.n} d={ds.d} (clustered synthetic, SIFT dims)")

    print("data owner: encrypting database + building DCPE-HNSW index ...")
    owner, user, server = ppanns.build_system(
        ds.base, beta_fraction=0.03, M=16, ef_construction=120, seed=7)
    print(f"  DCPE ciphertexts: {server.db.C_sap.shape}  "
          f"DCE ciphertexts: {server.db.C_dce.shape}")

    k = 10
    found, lat = [], []
    for q in ds.queries:
        c_sap, t_q = user.encrypt_query(q)          # user-side O(d^2)
        ids, stats = server.search(c_sap, t_q, k, ratio_k=8, ef_search=128)
        found.append(ids)
        lat.append(stats.latency_s)
    rec = synth.recall_at_k(np.stack(found), ds.gt, k)
    print(f"server-side search: recall@{k} = {rec:.3f}, "
          f"median latency {1e3 * np.median(lat):.1f} ms, "
          f"QPS ~ {1.0 / np.median(lat):.1f}")

    # what the server never sees: plaintexts or distances
    c_sap, t_q = user.encrypt_query(ds.queries[0])
    ids, stats = server.search(c_sap, t_q, k)
    print(f"bytes up per query: {stats.bytes_up} (O(d)); "
          f"bytes down: {stats.bytes_down} (4k)")
    print(f"refine comparisons: {stats.refine_comparisons} "
          f"(each leaks only a sign, Theorem 3)")
    assert rec >= 0.85
    print("OK")


if __name__ == "__main__":
    main()
