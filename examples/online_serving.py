"""Online serving runtime quickstart (DESIGN.md §8): multi-tenant
collections, live encrypted ingestion, dynamic micro-batching, and
telemetry.

  PYTHONPATH=src python examples/online_serving.py [--n 4000]

Two tenants share one runtime; each collection has its own keys, so the
server routes by (tenant, collection) and one tenant's trapdoors never
touch another's ciphertexts.  Queries from concurrent clients coalesce
into padded batches; inserts are visible to the next search; deleted ids
never come back.
"""

from __future__ import annotations

import argparse
import time

import numpy as np

from repro.core import dcpe
from repro.data import synth
from repro.serving.runtime import CollectionManager, TenantIsolationError


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=4000)
    ap.add_argument("--k", type=int, default=10)
    args = ap.parse_args(argv)

    ds = synth.make_dataset("sift1m", n=args.n, n_queries=24, d=64,
                            k_gt=args.k, seed=0)
    beta = dcpe.suggest_beta(ds.base, fraction=0.03)

    with CollectionManager(sap_beta=beta, max_wait_ms=4.0) as mgr:
        # -- two tenants, each with their own keys and index backend
        acme = mgr.create_collection("acme", "docs", d=64, backend="flat",
                                     seed=1)
        globex = mgr.create_collection("globex", "docs", d=64,
                                       backend="ivf", seed=2,
                                       n_partitions=32, nprobe=8)

        # -- live encrypted ingestion (owner-side jitted DCPE+DCE encrypt)
        t0 = time.time()
        acme.insert(ds.base)
        globex.insert(ds.base[: args.n // 2])
        print(f"ingested {args.n + args.n // 2} vectors across 2 tenants "
              f"in {time.time() - t0:.2f}s")
        acme.compact()
        acme.warmup(k=args.k)

        # -- concurrent single-query clients coalesce into batches
        user = acme.new_user()
        enc = [user.encrypt_query(q) for q in ds.queries]
        t0 = time.time()
        futs = [acme.submit(c, t, args.k) for c, t in enc]
        ids = np.stack([f.result(timeout=60) for f in futs])
        rec = synth.recall_at_k(ids, ds.gt, args.k)
        snap = acme.stats()
        print(f"acme/docs: {len(enc)} concurrent clients in "
              f"{time.time() - t0:.2f}s  recall@{args.k}={rec:.3f}  "
              f"occupancy={snap['batch_occupancy']:.1f}  "
              f"p99={1e3 * snap['p99_latency_s']:.1f}ms")

        # -- mutations: the next search sees them
        planted = acme.insert(ds.queries[0][None])
        ids1 = acme.search(*enc[0], args.k)
        assert planted[0] in ids1, "insert must be immediately visible"
        acme.delete(planted)
        ids2 = acme.search(*enc[0], args.k)
        assert planted[0] not in ids2, "deleted id must never return"
        print(f"mutation semantics: planted id {int(planted[0])} "
              "visible after insert, gone after delete")

        # -- strict tenant routing
        try:
            mgr.search("initech", "docs", *enc[0], args.k)
        except TenantIsolationError as e:
            print(f"tenant isolation: {e}")

        print("telemetry:", {k: (round(v, 4) if isinstance(v, float) else v)
                             for k, v in acme.stats().items()})


if __name__ == "__main__":
    main()
