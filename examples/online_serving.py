"""Online serving through the public API (`repro.api`, DESIGN.md §8/§9):
multi-tenant collections, live encrypted ingestion, dynamic
micro-batching, and telemetry — with the roles split the way the threat
model splits them.

  PYTHONPATH=src python examples/online_serving.py [--n 4000]

Two tenants share one keyless service; each tenant's `DataOwnerClient`
holds its own keys, so the service routes by (tenant, collection) and
one tenant's trapdoors never touch another's ciphertexts.  Queries from
concurrent clients coalesce into padded batches; inserts are visible to
the next search; deleted ids never come back.
"""

from __future__ import annotations

import argparse
import time
from concurrent.futures import ThreadPoolExecutor

import numpy as np

from repro.api import (DataOwnerClient, IndexSpec, SearchParams,
                       SecureAnnService, TenantIsolationError,
                       suggest_beta)
from repro.data import synth


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=4000)
    ap.add_argument("--k", type=int, default=10)
    args = ap.parse_args(argv)

    ds = synth.make_dataset("sift1m", n=args.n, n_queries=24, d=64,
                            k_gt=args.k, seed=0)
    beta = suggest_beta(ds.base, fraction=0.03)
    params = SearchParams(k=args.k)

    with SecureAnnService(max_wait_ms=4.0) as svc:
        # -- two tenants, each with their own keys and index backend
        acme_spec = IndexSpec(tenant="acme", name="docs", d=64,
                              backend="flat", sap_beta=beta, seed=1)
        globex_spec = IndexSpec(tenant="globex", name="docs", d=64,
                                backend="ivf", sap_beta=beta, seed=2,
                                n_partitions=32, nprobe=8)
        svc.create_collection(acme_spec)
        svc.create_collection(globex_spec)
        acme = DataOwnerClient(acme_spec)       # keys live client-side
        globex = DataOwnerClient(globex_spec)

        # -- live encrypted ingestion (owner-side jitted DCPE+DCE
        #    encrypt; the service ingests ciphertexts only)
        t0 = time.time()
        svc.insert("acme", "docs", *acme.encrypt_vectors(ds.base))
        svc.insert("globex", "docs",
                   *globex.encrypt_vectors(ds.base[: args.n // 2]))
        print(f"ingested {args.n + args.n // 2} vectors across 2 tenants "
              f"in {time.time() - t0:.2f}s")
        svc.compact("acme", "docs")
        svc.warmup("acme", "docs", k=args.k)

        # -- concurrent single-query clients coalesce into batches
        user = acme.query_client()
        reqs = [user.request("acme", "docs", q, params)
                for q in ds.queries]
        t0 = time.time()
        with ThreadPoolExecutor(len(reqs)) as pool:
            ids = np.concatenate([r.ids for r in pool.map(svc.submit, reqs)])
        rec = synth.recall_at_k(ids, ds.gt, args.k)
        snap = svc.stats("acme", "docs")
        print(f"acme/docs: {len(reqs)} concurrent clients in "
              f"{time.time() - t0:.2f}s  recall@{args.k}={rec:.3f}  "
              f"occupancy={snap['batch_occupancy']:.1f}  "
              f"p99={1e3 * snap['p99_latency_s']:.1f}ms")

        # -- mutations: the next search sees them
        planted = svc.insert("acme", "docs",
                             *acme.encrypt_vectors(ds.queries[0][None]))
        ids1 = svc.submit(reqs[0]).ids[0]
        assert planted[0] in ids1, "insert must be immediately visible"
        svc.delete("acme", "docs", planted)
        ids2 = svc.submit(reqs[0]).ids[0]
        assert planted[0] not in ids2, "deleted id must never return"
        print(f"mutation semantics: planted id {int(planted[0])} "
              "visible after insert, gone after delete")

        # -- strict tenant routing
        try:
            svc.submit(user.request("initech", "docs", ds.queries[0],
                                    params))
        except TenantIsolationError as e:
            print(f"tenant isolation: {e}")

        print("telemetry:", {k: (round(v, 4) if isinstance(v, float) else v)
                             for k, v in svc.stats("acme", "docs").items()})


if __name__ == "__main__":
    main()
