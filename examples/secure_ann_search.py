"""The paper's experimental pipeline end-to-end, through the public API
(`repro.api`, DESIGN.md §9): per-query Algorithm 2 against a service,
the unified batched engine over all three filter backends (DESIGN.md
§2), the TPU-native distributed scan, and the §III attack demonstration.

  PYTHONPATH=src python examples/secure_ann_search.py [--n 8000]
"""

import argparse
import dataclasses
import time

import numpy as np

from repro.api import (DataOwnerClient, IndexSpec, PlacementSpec,
                       SearchParams, SecureAnnService, suggest_beta)
from repro.core import attacks
from repro.data import synth


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=8000)
    ap.add_argument("--queries", type=int, default=20)
    args = ap.parse_args()

    ds = synth.make_dataset("deep1m", n=args.n, n_queries=args.queries,
                            k_gt=50, seed=1)
    k = 10
    params = SearchParams(k=k, ratio_k=8, ef_search=128)

    # ---- 1. three-role flow (the paper's Algorithm 2), one query at a
    #         time through the service's micro-batcher
    spec = IndexSpec(tenant="demo", name="deep", d=ds.d, backend="hnsw",
                     sap_beta=suggest_beta(ds.base, fraction=0.03),
                     hnsw_M=16, hnsw_ef_construction=120, seed=0)
    owner = DataOwnerClient(spec)
    corpus = owner.encrypt_corpus(ds.base)      # ciphertexts + owner HNSW
    user = owner.query_client()

    with SecureAnnService() as svc:
        svc.create_collection(spec, corpus=corpus)
        t0 = time.time()
        found = [svc.submit(user.request(spec.tenant, spec.name, q,
                                         params)).ids[0]
                 for q in ds.queries]
        rec = synth.recall_at_k(np.stack(found), ds.gt, k)
        print(f"[hnsw-dce] recall@{k}={rec:.3f}  "
              f"{args.queries / (time.time() - t0):.1f} QPS")

        # ---- 2. the unified batched engine: one jitted refine per
        #         batch, identical ids to the per-query path, any filter
        #         backend — three collections share the one corpus
        batch_req = user.request(spec.tenant, spec.name, ds.queries,
                                 params)
        recs = {}
        for backend in ("hnsw", "flat", "ivf"):
            bspec = dataclasses.replace(spec, name=f"deep-{backend}",
                                        backend=backend)
            svc.create_collection(bspec, corpus=corpus)
            req = dataclasses.replace(batch_req, collection=bspec.name,
                                      coalesce=False)
            t0 = time.time()
            res = svc.submit(req)
            recs[backend] = synth.recall_at_k(res.ids, ds.gt, k)
            print(f"[batched/{backend}] recall@{k}={recs[backend]:.3f}  "
                  f"{args.queries / (time.time() - t0):.1f} QPS  "
                  f"dist_evals={res.stats.filter_dist_evals}")
        rec2 = recs["flat"]

    # ---- 3. sharded deployment: the SAME service surface, placement
    #         as a parameter (row-sharded shard_map filter + sharded
    #         refine across every local device, DESIGN.md §10)
    with SecureAnnService() as svc:
        sspec = dataclasses.replace(spec, name="deep-sharded",
                                    backend="flat")
        svc.create_collection(sspec, corpus=corpus,
                              placement=PlacementSpec(kind="sharded"))
        sreq = dataclasses.replace(batch_req, collection=sspec.name,
                                   coalesce=False)
        t0 = time.time()
        res = svc.submit(sreq)
        rec3 = synth.recall_at_k(res.ids, ds.gt, k)
        pl = svc.placement(sspec.tenant, sspec.name)
        print(f"[sharded/{pl.n_shards}-dev] recall@{k}={rec3:.3f}  "
              f"{args.queries / (time.time() - t0):.1f} QPS "
              f"(exact filter, backend={res.stats.backend})")

    # ---- 4. why DCE instead of ASPE: the §III KPA attack
    res_a = attacks.attack_roundtrip(d=12, n=100, nq=30, transform="linear")
    print(f"[attack] ASPE-linear KPA: query recovery err "
          f"{res_a['query_err']:.2e}, db recovery err {res_a['db_err']:.2e} "
          f"(broken; DCE leaks only comparison signs)")
    assert rec >= 0.85 and rec2 >= 0.9 and rec3 >= 0.9
    print("OK")


if __name__ == "__main__":
    main()
