"""The paper's experimental pipeline end-to-end: per-query Algorithm 2,
the unified batched engine over all three filter backends (DESIGN.md §2),
the TPU-native distributed scan, and the §III attack demonstration.

  PYTHONPATH=src python examples/secure_ann_search.py [--n 8000]
"""

import argparse
import time

import numpy as np

from repro.core import attacks, ppanns
from repro.data import synth
from repro.serving import (DistributedSecureANN, HNSWGraphFilter,
                           SecureSearchEngine)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=8000)
    ap.add_argument("--queries", type=int, default=20)
    args = ap.parse_args()

    ds = synth.make_dataset("deep1m", n=args.n, n_queries=args.queries,
                            k_gt=50, seed=1)
    k = 10

    # ---- 1. single-server filter-and-refine (the paper's Algorithm 2)
    owner, user, server = ppanns.build_system(ds.base, beta_fraction=0.03,
                                              M=16, ef_construction=120)
    t0 = time.time()
    found = []
    for q in ds.queries:
        c_sap, t_q = user.encrypt_query(q)
        ids, _ = server.search(c_sap, t_q, k, ratio_k=8, ef_search=128)
        found.append(ids)
    rec = synth.recall_at_k(np.stack(found), ds.gt, k)
    print(f"[hnsw-dce] recall@{k}={rec:.3f}  "
          f"{args.queries / (time.time() - t0):.1f} QPS")

    # ---- 2. the unified batched engine: one jitted refine per batch,
    #         identical ids to the per-query path, any filter backend
    C_sap = np.asarray(server.db.C_sap)
    C_dce = np.asarray(server.db.C_dce)
    qs, ts_ = zip(*(user.encrypt_query(q) for q in ds.queries))
    Q, T = np.stack(qs), np.stack(ts_)
    backends = {
        "hnsw": SecureSearchEngine(C_sap, C_dce,
                                   backend=HNSWGraphFilter(server.db.index)),
        "flat": SecureSearchEngine(C_sap, C_dce, backend="flat"),
        "ivf": SecureSearchEngine(C_sap, C_dce, backend="ivf",
                                  n_partitions=64, nprobe=8),
    }
    recs = {}
    for name, engine in backends.items():
        t0 = time.time()
        ids, stats = engine.search_batch(Q, T, k=k, ratio_k=8,
                                         ef_search=128)
        recs[name] = synth.recall_at_k(ids, ds.gt, k)
        print(f"[batched/{name}] recall@{k}={recs[name]:.3f}  "
              f"{args.queries / (time.time() - t0):.1f} QPS  "
              f"dist_evals={stats.filter_dist_evals}")
    rec2 = recs["flat"]

    # ---- 3. distributed sharded secure scan (TPU-native deployment)
    eng = DistributedSecureANN(C_sap, C_dce)
    t0 = time.time()
    ids = eng.query_batch(Q, T, k=k, ratio_k=8)
    rec3 = synth.recall_at_k(ids, ds.gt, k)
    print(f"[dist-scan] recall@{k}={rec3:.3f}  "
          f"{args.queries / (time.time() - t0):.1f} QPS (exact filter)")

    # ---- 4. why DCE instead of ASPE: the §III KPA attack
    res = attacks.attack_roundtrip(d=12, n=100, nq=30, transform="linear")
    print(f"[attack] ASPE-linear KPA: query recovery err "
          f"{res['query_err']:.2e}, db recovery err {res['db_err']:.2e} "
          f"(broken; DCE leaks only comparison signs)")
    assert rec >= 0.85 and rec2 >= 0.9 and rec3 >= 0.9
    print("OK")


if __name__ == "__main__":
    main()
