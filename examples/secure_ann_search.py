"""The paper's experimental pipeline end-to-end, including the TPU-native
distributed scan and the §III attack demonstration.

  PYTHONPATH=src python examples/secure_ann_search.py [--n 8000]
"""

import argparse
import time

import numpy as np

from repro.core import aspe, attacks, dce, dcpe, ppanns
from repro.data import synth
from repro.serving import DistributedSecureANN


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=8000)
    ap.add_argument("--queries", type=int, default=20)
    args = ap.parse_args()

    ds = synth.make_dataset("deep1m", n=args.n, n_queries=args.queries,
                            k_gt=50, seed=1)
    k = 10

    # ---- 1. single-server filter-and-refine (the paper's Algorithm 2)
    owner, user, server = ppanns.build_system(ds.base, beta_fraction=0.03,
                                              M=16, ef_construction=120)
    t0 = time.time()
    found = []
    for q in ds.queries:
        c_sap, t_q = user.encrypt_query(q)
        ids, _ = server.search(c_sap, t_q, k, ratio_k=8, ef_search=128)
        found.append(ids)
    rec = synth.recall_at_k(np.stack(found), ds.gt, k)
    print(f"[hnsw-dce] recall@{k}={rec:.3f}  "
          f"{args.queries / (time.time() - t0):.1f} QPS")

    # ---- 2. distributed sharded secure scan (TPU-native path)
    C_sap = server.db.C_sap
    C_dce = server.db.C_dce
    eng = DistributedSecureANN(np.asarray(C_sap), np.asarray(C_dce))
    qs, ts_ = zip(*(user.encrypt_query(q) for q in ds.queries))
    t0 = time.time()
    ids = eng.query_batch(np.stack(qs), np.stack(ts_), k=k, ratio_k=8)
    rec2 = synth.recall_at_k(ids, ds.gt, k)
    print(f"[dist-scan] recall@{k}={rec2:.3f}  "
          f"{args.queries / (time.time() - t0):.1f} QPS (exact filter)")

    # ---- 3. why DCE instead of ASPE: the §III KPA attack
    res = attacks.attack_roundtrip(d=12, n=100, nq=30, transform="linear")
    print(f"[attack] ASPE-linear KPA: query recovery err "
          f"{res['query_err']:.2e}, db recovery err {res['db_err']:.2e} "
          f"(broken; DCE leaks only comparison signs)")
    assert rec >= 0.85 and rec2 >= 0.9
    print("OK")


if __name__ == "__main__":
    main()
